"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import flash_attention, rglru_scan

KEY = jax.random.PRNGKey(7)


def qkv(b, s, h, kv, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d)).astype(dtype)
    return q, k, v


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


class TestFlashAttention:
    @pytest.mark.parametrize("b,s,h,kv,d", [
        (1, 128, 1, 1, 64),
        (2, 256, 4, 2, 64),
        (1, 512, 8, 8, 128),
        (2, 384, 6, 2, 64),      # non-power-of-two seq (divisible blocks)
        (1, 256, 4, 1, 128),     # MQA
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_sweep(self, b, s, h, kv, d, dtype):
        q, k, v = qkv(b, s, h, kv, d, dtype)
        out = flash_attention(q, k, v, True, 0)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            atol=TOL[dtype], rtol=TOL[dtype])

    @pytest.mark.parametrize("window", [64, 128, 256])
    def test_sliding_window(self, window):
        q, k, v = qkv(1, 512, 4, 2, 64, jnp.float32)
        out = flash_attention(q, k, v, True, window)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    def test_noncausal(self):
        q, k, v = qkv(2, 256, 4, 4, 64, jnp.float32)
        out = flash_attention(q, k, v, False, 0)
        want = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    def test_grads_match_reference(self):
        q, k, v = qkv(1, 256, 2, 2, 64, jnp.float32)

        def f_kernel(q, k, v):
            return jnp.sum(flash_attention(q, k, v, True, 0) ** 2)

        def f_ref(q, k, v):
            return jnp.sum(ref.flash_attention_ref(q, k, v, causal=True) ** 2)

        g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-4, rtol=1e-4)

    def test_jit_compatible(self):
        q, k, v = qkv(1, 256, 2, 2, 64, jnp.float32)
        out = jax.jit(lambda *a: flash_attention(*a, True, 0))(q, k, v)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


class TestRglruScan:
    @pytest.mark.parametrize("b,s,r", [
        (1, 256, 128), (2, 512, 256), (3, 256, 384),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, b, s, r, dtype):
        ks = jax.random.split(KEY, 2)
        a = (jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, r))) * 0.2
             + 0.8).astype(dtype)
        bb = (0.1 * jax.random.normal(ks[1], (b, s, r))).astype(dtype)
        h = rglru_scan(a.astype(jnp.float32), bb.astype(jnp.float32))
        want = ref.rglru_scan_ref(a.astype(jnp.float32),
                                  bb.astype(jnp.float32))
        np.testing.assert_allclose(np.asarray(h), np.asarray(want),
                                   atol=3e-5, rtol=3e-5)

    def test_batched_leading_dims(self):
        ks = jax.random.split(KEY, 2)
        a = jax.nn.sigmoid(jax.random.normal(ks[0], (2, 2, 256, 128)))
        b = 0.1 * jax.random.normal(ks[1], (2, 2, 256, 128))
        h = rglru_scan(a, b)
        want = ref.rglru_scan_ref(a, b)
        np.testing.assert_allclose(np.asarray(h), np.asarray(want),
                                   atol=3e-5, rtol=3e-5)

    def test_grad_adjoint(self):
        ks = jax.random.split(KEY, 2)
        a = jax.nn.sigmoid(jax.random.normal(ks[0], (1, 256, 128))) * 0.5
        b = 0.1 * jax.random.normal(ks[1], (1, 256, 128))
        ga = jax.grad(lambda a: jnp.sum(rglru_scan(a, b) ** 2))(a)
        gr = jax.grad(lambda a: jnp.sum(ref.rglru_scan_ref(a, b) ** 2))(a)
        np.testing.assert_allclose(np.asarray(ga), np.asarray(gr),
                                   atol=1e-4, rtol=1e-4)

    def test_decay_stability(self):
        """|a| < 1 keeps h bounded over long sequences."""
        a = jnp.full((1, 2048, 64), 0.99)
        b = jnp.ones((1, 2048, 64)) * 0.01
        h = rglru_scan(a, b)
        assert float(jnp.max(jnp.abs(h))) < 2.0
