"""Scheduler semantics (paper §3.2.2, Fig. 12)."""
import pytest

from repro.core.events import LiveOp, Op, ResourceSpec, LINK
from repro.core.schedulers import (FifoScheduler, Http2Scheduler,
                                   OrderedScheduler, make_link_scheduler)

RES = {"downlink": ResourceSpec("downlink", LINK, 1e6)}


def live(size, priority=0.0, name="op"):
    op = Op(name=name, res="downlink", size=size, priority=priority)
    return LiveOp.fresh(op, worker=0, step_seq=0, resources=RES)


def drain(sched):
    """Drive the simulator's protocol: on a non-last chunk's completion the
    op is re-added to the back of the queue (requeue-at-completion)."""
    out = []
    while sched:
        c = sched.remove_chunk()
        out.append((c.op.name, c.remaining, c.is_last))
        if not c.is_last:
            sched.add(c.op)
    return out


class TestHttp2:
    def test_small_stream_single_chunk(self):
        s = Http2Scheduler(win=100)
        s.add(live(60, name="a"))
        c = s.remove_chunk()
        assert c.is_last and c.remaining == 60
        assert not s

    def test_large_stream_preempted_once(self):
        """First service: WIN bytes; second service: the remainder, whole.
        The simulator re-adds the op when the burst COMPLETES."""
        s = Http2Scheduler(win=100)
        s.add(live(250, name="a"))
        c1 = s.remove_chunk()
        assert not c1.is_last and c1.remaining == 100
        s.add(c1.op)                                # burst completed
        c2 = s.remove_chunk()
        assert c2.is_last and c2.remaining == 150   # remainder, not 250
        assert not s

    def test_win_carved_out_of_remaining_work(self):
        """Regression: the second service must transmit size - WIN."""
        s = Http2Scheduler(win=100)
        op = live(250, name="a")
        s.add(op)
        s.remove_chunk()
        assert op.remaining_work == 150

    def test_preempted_stream_goes_to_back(self):
        """Streams that arrive DURING the burst are served before the
        preempted remainder (requeue-at-completion, Fig. 12)."""
        s = Http2Scheduler(win=100)
        s.add(live(250, name="big"))
        first = s.remove_chunk()
        assert first.op.name == "big" and not first.is_last
        s.add(live(50, name="small"))               # arrives mid-burst
        s.add(first.op)                             # burst completes
        second = s.remove_chunk()
        assert second.op.name == "small"
        third = s.remove_chunk()
        assert third.op.name == "big" and third.is_last

    def test_exactly_win_not_preempted(self):
        s = Http2Scheduler(win=100)
        s.add(live(100, name="a"))
        c = s.remove_chunk()
        assert c.is_last and c.remaining == 100

    def test_second_service_runs_to_completion_even_if_large(self):
        """Stream preemption happens only once (paper observation)."""
        s = Http2Scheduler(win=100)
        s.add(live(1000, name="a"))
        c1 = s.remove_chunk()
        assert c1.remaining == 100
        s.add(c1.op)
        c2 = s.remove_chunk()
        assert c2.is_last and c2.remaining == 900   # >> WIN, still whole

    def test_bad_win(self):
        with pytest.raises(ValueError):
            Http2Scheduler(win=0)


class TestFifoOrdered:
    def test_fifo_order(self):
        s = FifoScheduler()
        for n in "abc":
            s.add(live(10, name=n))
        assert [s.remove_chunk().op.name for _ in "abc"] == list("abc")

    def test_fifo_whole_streams(self):
        s = FifoScheduler()
        s.add(live(1e9, name="a"))
        c = s.remove_chunk()
        assert c.is_last and c.remaining == 1e9

    def test_ordered_by_priority(self):
        s = OrderedScheduler()
        s.add(live(10, priority=2, name="c"))
        s.add(live(10, priority=0, name="a"))
        s.add(live(10, priority=1, name="b"))
        assert [s.remove_chunk().op.name for _ in "abc"] == list("abc")

    def test_ordered_ties_by_arrival(self):
        s = OrderedScheduler()
        s.add(live(10, priority=0, name="a"))
        s.add(live(10, priority=0, name="b"))
        assert s.remove_chunk().op.name == "a"

    def test_factory(self):
        assert isinstance(make_link_scheduler("http2"), Http2Scheduler)
        assert isinstance(make_link_scheduler("fifo"), FifoScheduler)
        assert isinstance(make_link_scheduler("ordered"), OrderedScheduler)
        with pytest.raises(ValueError):
            make_link_scheduler("nope")
