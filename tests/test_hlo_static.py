"""Static HLO profiler: trip counts, dot FLOPs, collective wire factors."""
import pytest

from repro.core.hlo_static import (_coll_wire, _fusion_hbm_bytes,
                                   _group_size, _type_bytes,
                                   parse_hlo_profile)

TOY = """
HloModule toy

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %w = f32[8,8] constant({...})
  %d = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%i2, %d)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(6)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%zero, %x)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"6"}}
  %r = f32[8,8] get-tuple-element(%w), index=1
  ROOT %ar = f32[8,8]{1,0} all-reduce(%r), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""


class TestParse:
    def test_trip_count_applied_to_dots(self):
        p = parse_hlo_profile(TOY)
        # one dot of 2*8*8*8 = 1024 flops, x6 trips
        assert p.flops == pytest.approx(6 * 2 * 8 * 8 * 8)

    def test_collective_wire(self):
        p = parse_hlo_profile(TOY)
        # all-reduce f32[8,8]=256B over groups of 4: 2*(3/4)*256 = 384
        assert p.collective_by_kind["all-reduce"] == pytest.approx(384)

    def test_entry_detected(self):
        p = parse_hlo_profile(TOY)
        comps = {o.comp for o in p.ops}
        assert "main" in comps and "body" in comps


class TestHelpers:
    def test_type_bytes(self):
        assert _type_bytes("f32[4,4]{1,0}") == 64
        assert _type_bytes("bf16[10]") == 20
        assert _type_bytes("(f32[2], s8[8])") == 16
        assert _type_bytes("pred[]") == 1

    def test_group_size_explicit_and_iota(self):
        assert _group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4
        assert _group_size("replica_groups=[16,32]<=[512]") == 32
        assert _group_size("no groups here") == 1

    @pytest.mark.parametrize("kind,out,inb,n,want", [
        ("all-reduce", 1000, 1000, 4, 1500),        # 2*(3/4)*out
        ("all-gather", 1600, 100, 4, 1200),         # (3/4)*gathered
        ("reduce-scatter", 100, 1600, 4, 1200),     # (3/4)*unscattered
        ("all-to-all", 1000, 1000, 4, 750),
        ("collective-permute", 500, 500, 1, 500),
        ("all-reduce", 1000, 1000, 1, 0),           # single participant
    ])
    def test_wire_factors(self, kind, out, inb, n, want):
        assert _coll_wire(kind, out, inb, n) == want

    def test_fusion_artifact_names(self):
        assert _fusion_hbm_bytes("transpose_copy_fusion.3", 100, 100, 80) \
            == 0
        assert _fusion_hbm_bytes("wrapped_convert", 100, 100, 80) == 0
        assert _fusion_hbm_bytes("add_multiply_fusion", 100, 60, 80) == 160
        # DUS fusions count only the updated slice
        assert _fusion_hbm_bytes(
            "dynamic-update-slice_convert_fusion", 1000 + 8, 1000, 1000) == 8


class TestTpuAdapter:
    def test_dag_acyclic_and_predicts(self):
        from repro.configs import get_config
        from repro.core.tpu_adapter import (MeshFactors, build_step_dag,
                                            predict_step_time)
        cfg = get_config("granite-8b")
        mesh = MeshFactors()
        dag = build_step_dag(cfg, mesh, tokens_global=4096 * 256)
        t1 = predict_step_time(dag)
        assert 0.01 < t1 < 100.0

    def test_straggler_slows_step(self):
        from repro.configs import get_config
        from repro.core.tpu_adapter import (MeshFactors, build_step_dag,
                                            predict_step_time)
        cfg = get_config("granite-8b")
        dag = build_step_dag(cfg, MeshFactors(), tokens_global=4096 * 256)
        t1 = predict_step_time(dag)
        t2 = predict_step_time(dag, straggler_factor=1.5)
        assert t2 > t1

    def test_more_pods_scale_throughput(self):
        from repro.configs import get_config
        from repro.core.tpu_adapter import (MeshFactors, build_step_dag,
                                            predict_step_time)
        cfg = get_config("granite-8b")
        tok = 4096 * 256
        t1 = predict_step_time(build_step_dag(
            cfg, MeshFactors(pods=1), tok), num_pods=1)
        t2 = predict_step_time(build_step_dag(
            cfg, MeshFactors(pods=2), tok), num_pods=2)
        # per-step time drops (same global batch over 2x chips), though not
        # perfectly: DCN all-reduce is added
        assert t2 < t1
        assert t2 > t1 / 2.2

    def test_compression_helps_dcn(self):
        from repro.configs import get_config
        from repro.core.tpu_adapter import (MeshFactors, build_step_dag,
                                            predict_step_time)
        cfg = get_config("llama-3.2-vision-90b")
        tok = 4096 * 256
        m = MeshFactors(pods=2)
        t_fp = predict_step_time(build_step_dag(cfg, m, tok), num_pods=2)
        t_c = predict_step_time(
            build_step_dag(cfg, m, tok, compressed_dcn=0.25), num_pods=2)
        assert t_c <= t_fp
