"""Paper-level validation: prediction error bands against the emulator.

These are the reproduction claims of DESIGN.md §1 (scaled down for CI):
  * private CPU cluster: error <= 12% across batch sizes / models / W
    (paper: 10%; we allow 2 points of slack for the smaller sample sizes
    used in CI — the full benchmark uses the paper's sizes);
  * flow-control-off + enforced orders predict within 12%;
  * noise-free platform: near-exact (<= 3%);
  * baselines are WORSE than our method on the saturated regime.
"""
import dataclasses

import pytest

from repro.core.paper_models import PLATFORMS, PRIVATE_CPU
from repro.core.predictor import PredictionRun, prediction_error

CLEAN = dataclasses.replace(
    PRIVATE_CPU, name="clean_test", noise_compute=0.0, noise_bandwidth=0.0,
    win_sigma=0.0, bg_rate=0.0)
PLATFORMS.setdefault("clean_test", CLEAN)


def _run(**kw):
    kw.setdefault("profile_steps", 40)
    kw.setdefault("sim_steps", 250)
    kw.setdefault("platform", "private_cpu")
    r = PredictionRun(**kw)
    r.prepare()
    return r


class TestNoiseFreeExactness:
    def test_w1_near_exact(self):
        r = _run(dnn="alexnet", batch_size=8, platform="clean_test",
                 profile_steps=10, sim_steps=60)
        p, m = r.predict(1), r.measure(1, steps=40)
        assert prediction_error(p, m) < 0.03


class TestPrivateCpuBands:
    @pytest.mark.parametrize("batch", [4, 8, 16])
    def test_alexnet_batch_sizes(self, batch):
        r = _run(dnn="alexnet", batch_size=batch)
        for w in (1, 2, 4):
            err = prediction_error(r.predict(w),
                                   r.measure_mean(w, steps=150))
            # W=2 is the paper's own documented hard case (metastable
            # partial interleaving; the paper itself reports 20 % at W=2 on
            # its private cluster, Fig. 17b, and 30-40 % on cloud W=2-4)
            band = 0.30 if w == 2 else 0.15
            assert err < band, f"W={w} err={err:.1%}"

    @pytest.mark.parametrize("dnn", ["googlenet", "resnet50", "vgg11"])
    def test_other_models(self, dnn):
        r = _run(dnn=dnn, batch_size=8)
        for w in (1, 3):
            err = prediction_error(r.predict(w),
                                   r.measure_mean(w, steps=150))
            assert err < 0.12, f"{dnn} W={w} err={err:.1%}"


class TestFlowControlOff:
    @pytest.mark.parametrize("order", ["layer", "reverse", "random"])
    def test_enforced_orders(self, order):
        r = _run(dnn="alexnet", batch_size=8, flow_control=False,
                 order=order)
        for w in (1, 2, 4):
            err = prediction_error(r.predict(w),
                                   r.measure_mean(w, steps=150))
            band = 0.30 if w == 2 else 0.15
            assert err < band, f"order={order} W={w} err={err:.1%}"


class TestBaselinesWorse:
    def test_our_method_beats_baselines_at_saturation(self):
        """Paper §4.4: Lin saturates too early with large batch overlap;
        Cynthia underpredicts."""
        r = _run(dnn="alexnet", batch_size=16)
        w = 6
        meas = r.measure(w, steps=120)
        ours = prediction_error(r.predict(w), meas)
        lin = prediction_error(r.predict_baseline(w, "lin"), meas)
        cyn = prediction_error(r.predict_baseline(w, "cynthia"), meas)
        assert ours < max(lin, cyn)
        assert ours < 0.12


class TestTwoParameterServers:
    def test_two_ps_band(self):
        r = _run(dnn="vgg11", batch_size=8, num_ps=2, profile_steps=30,
                 sim_steps=200)
        for w in (1, 2, 4):
            err = prediction_error(r.predict(w), r.measure(w, steps=100))
            assert err < 0.25, f"2PS W={w} err={err:.1%}"

    def test_uneven_vgg_split(self):
        """Fig. 23: greedy layer assignment gives PS1 ~4x the bytes of
        PS2 for VGG-11 (fc6 dominates)."""
        from repro.core.paper_models import VGG11
        from repro.profiling.tracer import ps_split_bytes
        a, b = ps_split_bytes(VGG11, 2)
        hi, lo = max(a, b), min(a, b)
        assert hi / lo > 3.0
