"""Closed-loop calibration: planted-truth differential harness.

The backbone of the calibrate subsystem's correctness story:

* **planted truth** — synthesize traces from a known parameter set
  (``repro.calibrate.synth``) and assert the fitter recovers it: within
  tolerance under seeded noise, *exactly* at noise=0;
* **differential inertness** — a profile whose values equal the
  profiled medians and platform nominals leaves the DES bit-identical
  to the uncalibrated golden path (the PR 6 empty-FaultSpec pattern);
* **drift gate** — an unperturbed system never recalibrates; a
  perturbed one fires the gate, and refitting shrinks the error;
* **properties** — the fit is invariant under trace shuffling
  (hypothesis; order-statistic estimators sort internally).
"""
from __future__ import annotations

import random
from dataclasses import replace

import pytest
from _hypothesis_compat import given, settings, st

from repro.calibrate.extract import (extract_des_trace,
                                     extract_recorded_steps, load_traces,
                                     save_traces, template_sizes)
from repro.calibrate.fit import (CalibrationProfile, fit_profile,
                                 fit_residual_overhead, robust_location,
                                 theil_sen)
from repro.calibrate.loop import (ClosedLoop, fit_from_steps,
                                  identity_profile, should_recalibrate)
from repro.calibrate.synth import (make_truth, synthesize_parse_probes,
                                   synthesize_steps)
from repro.core.paper_models import PAPER_DNNS, PLATFORMS
from repro.core.predictor import PredictionRun
from repro.core.simulator import Simulation
from repro.emulator.cluster import observe_run
from repro.obs import ledger
from repro.obs.schema import validate_trace_meta

TRUTH = make_truth(layers=4, seed=3)


def _fit_synth(noise: float, steps: int = 50, seed: int = 1,
               probes: bool = True) -> CalibrationProfile:
    recorded = synthesize_steps(TRUTH, steps=steps, seed=seed, noise=noise)
    samples = extract_recorded_steps(recorded)
    if probes:
        samples.parse.extend(
            synthesize_parse_probes(TRUTH, seed=seed + 1, noise=noise))
    return fit_profile(samples)


def _worst_rel(prof: CalibrationProfile):
    exp = TRUTH.expected_op_times()
    op = max(abs(prof.op_times[n] - t) / t for n, t in exp.items())
    cap = max(abs(prof.link_capacity[l] - c) / c
              for l, c in TRUTH.link_capacity.items())
    return op, cap


# ------------------------------------------------------- planted truth


def test_planted_truth_recovery_under_noise():
    prof = _fit_synth(noise=0.05, steps=60)
    worst_op, worst_cap = _worst_rel(prof)
    assert worst_op < 0.05
    assert worst_cap < 0.08
    assert abs(prof.overhead_alpha - TRUTH.overhead.alpha) \
        / TRUTH.overhead.alpha < 0.10
    assert abs(prof.overhead_beta - TRUTH.overhead.beta) \
        / TRUTH.overhead.beta < 0.10


def test_noise_zero_exact_recovery():
    prof = _fit_synth(noise=0.0)
    worst_op, worst_cap = _worst_rel(prof)
    assert worst_op < 1e-9
    assert worst_cap < 1e-9
    assert prof.overhead_alpha == pytest.approx(TRUTH.overhead.alpha,
                                                rel=1e-9)
    assert prof.overhead_beta == pytest.approx(TRUTH.overhead.beta,
                                               rel=1e-9)


def test_prior_overhead_resolves_capacity_without_claiming_it():
    """Without direct parse samples the capacity/parse-rate split comes
    from the prior; the profile must then fit capacities exactly but NOT
    claim alpha/beta it could not identify."""
    recorded = synthesize_steps(TRUTH, steps=50, seed=1, noise=0.0)
    prof = fit_profile(extract_recorded_steps(recorded),
                       prior_overhead=TRUTH.overhead)
    _, worst_cap = _worst_rel(prof)
    assert worst_cap < 1e-9
    assert prof.overhead_alpha is None and prof.overhead_beta is None


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 6))
def test_fit_invariant_under_trace_shuffling(shuffle_seed):
    """Order-statistic estimators: any permutation of the steps (and of
    the ops within each step) yields the identical profile digest."""
    recorded = synthesize_steps(TRUTH, steps=30, seed=5, noise=0.03)
    probes = synthesize_parse_probes(TRUTH, seed=6, noise=0.03)
    rng = random.Random(shuffle_seed)
    shuffled = list(recorded)
    rng.shuffle(shuffled)
    for step in shuffled:
        rng.shuffle(step.ops)
    sh_probes = list(probes)
    rng.shuffle(sh_probes)

    base = extract_recorded_steps(recorded)
    base.parse.extend(probes)
    perm = extract_recorded_steps(shuffled)
    perm.parse.extend(sh_probes)
    assert fit_profile(base).digest == fit_profile(perm).digest


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 6))
def test_noise_zero_exact_for_any_seed(seed):
    prof = _fit_synth(noise=0.0, steps=20, seed=seed)
    worst_op, worst_cap = _worst_rel(prof)
    assert worst_op < 1e-9 and worst_cap < 1e-9


# ------------------------------------------------------ estimator units


def test_robust_location_rejects_outliers():
    xs = [1.0] * 20 + [100.0]
    assert robust_location(xs) == pytest.approx(1.0)


def test_theil_sen_recovers_line_and_sorts_input():
    pts = [(float(x), 3e-9 * x + 2e-3) for x in range(1, 40)]
    rng = random.Random(0)
    rng.shuffle(pts)
    a, b = theil_sen(pts)
    assert a == pytest.approx(3e-9, rel=1e-9)
    assert b == pytest.approx(2e-3, rel=1e-9)
    with pytest.raises(ValueError):
        theil_sen([(1.0, 1.0)])


def test_fit_residual_overhead():
    obs = [1.05, 1.06, 1.04, 1.05]
    pred = [1.00, 1.01, 0.99, 1.00]
    assert fit_residual_overhead(obs, pred) == pytest.approx(0.05,
                                                             abs=1e-3)
    # floored at zero: a model predicting too slow is not a residual
    assert fit_residual_overhead(pred, obs) == 0.0
    assert fit_residual_overhead([], obs) == 0.0


def test_residual_applied_to_last_compute_op():
    recorded = synthesize_steps(TRUTH, steps=10, seed=1, noise=0.0)
    samples = extract_recorded_steps(recorded)
    samples.parse.extend(synthesize_parse_probes(TRUTH))
    prof = fit_profile(samples)
    run = _base_run()
    plain = prof.apply_to_templates(run.sim_steps_templates,
                                    fallback_overhead=run.overhead)
    bumped = replace(prof, residual_overhead_s=0.25).apply_to_templates(
        run.sim_steps_templates, fallback_overhead=run.overhead)
    for a, b in zip(plain, bumped):
        deltas = [ob.duration - oa.duration
                  for oa, ob in zip(a.ops, b.ops)]
        assert sum(1 for d in deltas if d > 1e-12) == 1
        assert max(deltas) == pytest.approx(0.25)


# ------------------------------------------------- profile round trips


def test_profile_json_round_trip_and_digest_stability(tmp_path):
    prof = _fit_synth(noise=0.02)
    p = str(tmp_path / "prof.json")
    prof.save(p)
    back = CalibrationProfile.load(p)
    assert back.digest == prof.digest
    assert back.op_times == prof.op_times
    assert back.link_capacity == prof.link_capacity
    # digest covers parameters only: provenance must not perturb it
    assert replace(prof, provenance={"x": 1}).digest == prof.digest
    assert replace(prof, sample_counts={"steps": 9}).digest == prof.digest
    # ... and any parameter change must
    assert replace(prof, residual_overhead_s=0.1).digest != prof.digest


def test_profile_load_rejects_corruption(tmp_path):
    import json
    prof = _fit_synth(noise=0.02)
    doc = prof.to_dict()
    doc["overhead_beta"] = 123.0   # tamper without re-hashing
    p = tmp_path / "bad.json"
    p.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="digest mismatch"):
        CalibrationProfile.load(str(p))
    with pytest.raises(ValueError, match="version"):
        CalibrationProfile.from_dict({"version": 99})


def test_trace_corpus_round_trip(tmp_path):
    steps = synthesize_steps(TRUTH, steps=6, seed=2, noise=0.01)
    f1 = str(tmp_path / "a.json")
    save_traces(f1, steps[:3])
    save_traces(str(tmp_path / "b.json"), steps[3:])
    assert len(load_traces(f1)) == 3
    allsteps = load_traces(str(tmp_path))
    assert len(allsteps) == 6
    got = [(o.name, o.start, o.end, o.size) for s in allsteps for o in s.ops]
    want = [(o.name, o.start, o.end, o.size) for s in steps for o in s.ops]
    assert got == want
    (tmp_path / "junk.json").write_text("{}")
    with pytest.raises(ValueError, match="format"):
        load_traces(str(tmp_path / "junk.json"))
    with pytest.raises(FileNotFoundError):
        load_traces(str(tmp_path / "empty_dir"))


# ---------------------------------------------- differential inertness


_RUNS = {}


def _base_run() -> PredictionRun:
    if "base" not in _RUNS:
        _RUNS["base"] = PredictionRun(
            "alexnet", 64, "private_cpu", profile_steps=10,
            sim_steps=40, warmup_steps=5).prepare()
    return _RUNS["base"]


def _trace_of(run: PredictionRun, W: int = 3):
    cfg, tpls, _w, _b, _warm = run.prediction_tasks(W, 1)[0]
    cfg.record_trace = True
    return Simulation(cfg).run(tpls, W)


def test_identity_calibration_is_bit_inert():
    """The PR 6 empty-FaultSpec pattern: a calibration profile whose
    values equal the profiled medians and platform nominals must leave
    the simulation bit-identical to the uncalibrated golden path."""
    run = _base_run()
    cal = run.with_calibration(identity_profile(run))
    healthy = _trace_of(run)
    calibrated = _trace_of(cal)
    assert calibrated.step_completions == healthy.step_completions
    assert [r.end for r in calibrated.records] == \
        [r.end for r in healthy.records]
    assert [r.name for r in calibrated.records] == \
        [r.name for r in healthy.records]


def test_calibration_digest_stamped_and_schema_valid():
    run = _base_run()
    prof = identity_profile(run)
    cal = run.with_calibration(prof)
    t_cal, t_plain = _trace_of(cal), _trace_of(run)
    assert t_cal.meta["calibration_digest"] == prof.digest
    assert "calibration_digest" not in t_plain.meta
    assert validate_trace_meta(t_cal, strict=True) == []


def test_with_calibration_rebuilds_templates():
    """replace() carries prepared fields; with_calibration must rebuild
    the templates so the profile actually applies (and the None round
    trip must restore the pristine ones)."""
    run = _base_run()
    prof = identity_profile(run)
    doubled = replace(prof, op_times={k: 2.0 * v
                                      for k, v in prof.op_times.items()})
    cal = run.with_calibration(doubled)
    total0 = sum(op.duration for t in run.sim_steps_templates
                 for op in t.ops)
    total1 = sum(op.duration for t in cal.sim_steps_templates
                 for op in t.ops)
    assert total1 > 1.5 * total0
    back = cal.with_calibration(None)
    totalb = sum(op.duration for t in back.sim_steps_templates
                 for op in t.ops)
    assert totalb == total0


def test_des_trace_extraction_fits_overhead():
    """DES traces carry explicit */parse ops: extraction yields direct
    parse samples and the fit recovers the run's own overhead model."""
    run = _base_run()
    trace = _trace_of(run, W=2)
    samples = extract_des_trace(
        trace, size_of=template_sizes(run.sim_steps_templates))
    assert samples.parse and samples.op_times and samples.links
    prof = fit_profile(samples)
    assert prof.overhead_alpha == pytest.approx(run.overhead.alpha,
                                                rel=0.05)


# ------------------------------------------------------- drift trigger


def _perturbed_observe(factor_compute: float, factor_bw: float,
                       steps: int = 30):
    plat0 = PLATFORMS["private_cpu"]
    pert = replace(plat0,
                   worker_flops=plat0.worker_flops / factor_compute,
                   ps_update_bw=plat0.ps_update_bw / factor_compute,
                   bandwidth=plat0.bandwidth * factor_bw)

    def observe(run, W):
        return observe_run(PAPER_DNNS[run.dnn], run.batch_size, pert, W,
                           num_ps=run.num_ps, steps=steps,
                           seed=run.seed + 1000,
                           flow_control=run.flow_control, order=run.order,
                           warmup_steps=run.warmup_steps)
    return observe


def test_should_recalibrate_gate():
    assert not should_recalibrate(0.03, gate=0.05)
    assert should_recalibrate(0.08, gate=0.05)
    assert not should_recalibrate(0.30, 0.28, gate=0.05)
    assert should_recalibrate(0.30, 0.10, gate=0.05)


def test_unperturbed_system_never_recalibrates():
    run = _base_run()
    lp = ClosedLoop(run=run, num_workers=2,
                    observe=_perturbed_observe(1.0, 1.0), n_runs=1,
                    gate=0.10)
    for _ in range(2):
        res = lp.round()
        assert not res.recalibrated
        assert res.err_before < lp.gate
    assert lp.run.calibration is None


def test_perturbation_fires_gate_and_refit_shrinks_error():
    # W=3: the uncalibrated DES's intrinsic error floor is ~2% there
    # (vs ~5% at W=2), so the halving criterion tests the refit rather
    # than the model floor
    run = _base_run()
    lp = ClosedLoop(run=run, num_workers=3,
                    observe=_perturbed_observe(1.25, 0.7), n_runs=1,
                    gate=0.10)
    res = lp.round()
    assert res.recalibrated
    assert res.err_before > lp.gate
    assert res.err_after <= 0.5 * res.err_before
    assert lp.run.calibration is not None
    assert lp.run.calibration.digest == res.profile_digest


def test_refit_convergence_over_rounds(tmp_path, monkeypatch):
    """Three refit rounds on a drifted system: the end-of-round error
    never increases (beyond seed noise) and `recalibrated` ledger
    records accumulate with the profile digests."""
    monkeypatch.setenv("REPRO_LEDGER", str(tmp_path / "ledger.jsonl"))
    run = _base_run()
    lp = ClosedLoop(run=run, num_workers=2,
                    observe=_perturbed_observe(1.2, 0.75), n_runs=1,
                    refit="always")
    for _ in range(3):
        lp.round()
    errs = lp.errors()
    assert len(errs) == 3
    for a, b in zip(errs, errs[1:]):
        assert b <= a + 0.02
    assert errs[-1] <= errs[0]
    recs = ledger.read(str(tmp_path / "ledger.jsonl"))
    recal = [r for r in recs if r["kind"] == "recalibrated"]
    assert len(recal) == 3
    assert all(r["calibration_digest"] for r in recal)
    assert recal[-1]["corpus_steps"] > recal[0]["corpus_steps"]


def test_fit_from_steps_uses_run_prior():
    run = _base_run()
    _tp, steps = _perturbed_observe(1.0, 1.0)(run, 2)
    prof = fit_from_steps(steps, run=run)
    # nominal platform: fitted capacity within a few % of the nominal
    plat = PLATFORMS["private_cpu"]
    for cap in prof.link_capacity.values():
        assert abs(cap - plat.bandwidth) / plat.bandwidth < 0.10
    assert prof.sample_counts["steps"] == len(steps)
