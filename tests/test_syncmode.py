"""Synchronization-semantics subsystem: barriers, SSP, collectives.

The acceptance gates of the subsystem:

  * ``sync_mode="async"`` is bit-identical to the frozen reference engine
    on the golden traces (the controller is pure bookkeeping);
  * sync-barrier throughput matches the closed-form max-of-n bound on a
    degenerate one-op model with heterogeneous worker speeds;
  * ssp(s=0) reproduces sync(k=n) exactly and ssp(s=inf) reproduces async
    exactly — trace-for-trace, RNG draws and all;
  * ring all-reduce per-worker transfer volume is 2(n-1)/n * bytes, and
    the transformed step DAG carries no PS resources;
  * every mode reports a staleness distribution with the right shape, and
    the emulator's barrier semantics agree with the DES prediction.
"""
import random

import pytest

from repro.core import collectives
from repro.core.events import Op, StepTemplate, ps_resources
from repro.core.simulator import SimConfig, Simulation
from repro.core.simulator_ref import ReferenceSimulation
from repro.core.syncmode import (SyncSpec, allreduce_templates,
                                 make_controller, staleness_stats)
from repro.core.topology import Topology

from test_engine_equivalence import assert_equivalent, make_steps

BW = 1e8


def sim_kw(seed=0, **over):
    kw = dict(resources=ps_resources(BW), link_policy="http2", win=2.8e6,
              steps_per_worker=20, warmup_steps=5, seed=seed,
              record_trace=True, record_op_times=True, service_jitter=0.12,
              stall_alpha=2e-9, stall_rtt=1e-3)
    kw.update(over)
    return kw


# ---------------------------------------------------------------- validation


def test_spec_validation():
    with pytest.raises(ValueError, match="sync_mode"):
        SyncSpec(mode="bsp")
    with pytest.raises(ValueError, match="backup_workers"):
        SyncSpec(mode="async", backup_workers=1)
    with pytest.raises(ValueError, match="staleness_bound"):
        SyncSpec(mode="sync", staleness_bound=2)
    with pytest.raises(ValueError, match="allreduce_algo"):
        SyncSpec(mode="allreduce", allreduce_algo="butterfly")
    with pytest.raises(ValueError, match="quorum"):
        make_controller(SyncSpec(mode="sync", backup_workers=3), 3)


def test_backup_workers_validated_against_worker_count():
    tpl = StepTemplate(ops=[Op("c", "worker", duration=0.1)])
    cfg = SimConfig(**sim_kw(sync_mode="sync", backup_workers=2))
    with pytest.raises(ValueError, match="quorum"):
        Simulation(cfg).run([tpl], 2)


# ------------------------------------------------- async golden equivalence


@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("num_ps", [1, 2])
def test_async_mode_golden_trace(seed, num_ps):
    """sync_mode="async" must reproduce the frozen reference engine's
    traces exactly: the controller adds bookkeeping only."""
    rng = random.Random(1234 + seed)
    tpls = make_steps(rng, num_ps)
    kw = sim_kw(seed=seed, resources=ps_resources(BW, num_ps))
    if num_ps > 1:
        from repro.core.bandwidth import BandwidthModel
        kw["bandwidth_model"] = BandwidthModel()
    new = Simulation(SimConfig(sync_mode="async", **kw)).run(tpls, 3)
    ref = ReferenceSimulation(SimConfig(**kw)).run(tpls, 3)
    assert_equivalent(new, ref)
    assert new.meta["sync_mode"] == "async"
    assert new.meta["num_versions"] == len(new.step_completions)


# --------------------------------------------------- sync barrier semantics


def test_sync_barrier_matches_max_of_n_bound():
    """Degenerate 1-op model, no jitter: every synchronous step takes
    exactly max_i(d / speed_i), the closed-form max-of-n bound."""
    d = 0.1
    speeds = {0: 0.5, 1: 1.0, 2: 2.0}
    tpl = StepTemplate(ops=[Op("c", "worker", duration=d)])
    cfg = SimConfig(**sim_kw(sync_mode="sync", service_jitter=0.0,
                             stall_alpha=0.0, stall_rtt=0.0,
                             worker_speed=speeds))
    trace = Simulation(cfg).run([tpl], 3, sample=False)
    step_time = d / min(speeds.values())
    per_step = {}
    for w, s, t in trace.step_completions:
        per_step.setdefault(s, []).append(t)
    for s, times in per_step.items():
        # the barrier pins every worker's step s to the straggler's pace
        assert max(times) == pytest.approx((s + 1) * step_time, rel=1e-9)
    assert trace.meta["sim_end_time"] == pytest.approx(
        cfg.steps_per_worker * step_time, rel=1e-9)
    assert trace.staleness_stats()["max"] == 0


def test_backup_workers_drop_the_straggler():
    """With one backup worker the fast replicas commit without the
    straggler, whose gradients arrive stale (nonzero lag)."""
    tpl = StepTemplate(ops=[Op("c", "worker", duration=0.1)])
    base = sim_kw(service_jitter=0.0, stall_alpha=0.0, stall_rtt=0.0,
                  worker_speed={0: 0.25})
    full = Simulation(SimConfig(sync_mode="sync", **base)).run(
        [tpl], 3, sample=False)
    backup = Simulation(SimConfig(sync_mode="sync", backup_workers=1,
                                  **base)).run([tpl], 3, sample=False)
    # fast workers run at their own pace instead of the straggler's
    # (the straggler's own step budget fixes the overall makespan, so the
    # signal is when the fast replicas finish theirs)
    def fast_finish(trace):
        return max(t for w, _s, t in trace.step_completions if w != 0)

    assert fast_finish(backup) < fast_finish(full)
    assert full.staleness_stats()["max"] == 0
    assert backup.staleness_stats()["max"] >= 1
    # no silent truncation: every worker finishes its full step budget
    # even after the fast replicas retire and the barrier quorum shrinks
    # (regression: stale completions must not leak the in-flight census)
    assert len(backup.step_completions) == 3 * 20
    per_worker = {w: 0 for w in range(3)}
    for w, _s, _t in backup.step_completions:
        per_worker[w] += 1
    assert per_worker == {0: 20, 1: 20, 2: 20}


# ----------------------------------------------------------- ssp degeneracy


def test_ssp_zero_bound_equals_sync():
    rng = random.Random(7)
    tpls = make_steps(rng, 1)
    a = Simulation(SimConfig(sync_mode="ssp", staleness_bound=0,
                             **sim_kw())).run(tpls, 3)
    b = Simulation(SimConfig(sync_mode="sync", **sim_kw())).run(tpls, 3)
    # identical schedules, RNG draws and all (same release order) — but
    # the accounting differs by design: ssp applies updates one by one
    # (the k-th finisher of a round sees k-1 newer updates), while sync's
    # aggregated barrier commit reports lag 0
    assert_equivalent(a, b, rel=0.0)
    assert max(a.staleness) <= 2   # at most W-1 within one lockstep round
    assert max(b.staleness) == 0


def test_ssp_unbounded_equals_async():
    rng = random.Random(8)
    tpls = make_steps(rng, 1)
    a = Simulation(SimConfig(sync_mode="ssp", staleness_bound=10 ** 6,
                             **sim_kw())).run(tpls, 3)
    b = Simulation(SimConfig(sync_mode="async", **sim_kw())).run(tpls, 3)
    assert_equivalent(a, b, rel=0.0)
    assert a.staleness == b.staleness


def test_ssp_bounds_iteration_skew():
    """No worker's completed-iteration count may exceed the slowest by
    more than s at any completion."""
    s = 1
    tpl = StepTemplate(ops=[Op("c", "worker", duration=0.1)])
    cfg = SimConfig(**sim_kw(sync_mode="ssp", staleness_bound=s,
                             service_jitter=0.0, stall_alpha=0.0,
                             stall_rtt=0.0, worker_speed={0: 0.25}))
    trace = Simulation(cfg).run([tpl], 3, sample=False)
    completed = {0: 0, 1: 0, 2: 0}
    for w, _seq, _t in trace.step_completions:
        # the completing step was only allowed to start while its lead
        # over the slowest worker was within the bound
        assert completed[w] - min(completed.values()) <= s
        completed[w] += 1


# ------------------------------------------------------------- collectives


@pytest.mark.parametrize("n", [2, 3, 4, 8, 16])
def test_ring_volume_invariant(n):
    nbytes = 3.7e6
    assert collectives.ring_volume(n, nbytes) == \
        pytest.approx(2 * (n - 1) / n * nbytes)
    assert collectives.ring_rounds(n) == 2 * (n - 1)


def test_ring_duration_is_volume_over_rate():
    n, nbytes, bw = 4, 1e7, 1e8
    dur = collectives.allreduce_duration(nbytes, n, "ring", bw)
    assert dur == pytest.approx(collectives.ring_volume(n, nbytes) / bw)
    # per-round latency adds rounds * rtt
    rtt = 1e-3
    dur_rtt = collectives.allreduce_duration(nbytes, n, "ring", bw, rtt=rtt)
    assert dur_rtt == pytest.approx(dur + collectives.ring_rounds(n) * rtt)


def test_tree_wins_small_messages_ring_wins_large():
    bw, rtt, n = 1e8, 1e-3, 16
    small = 1e4
    large = 1e8
    assert (collectives.allreduce_duration(small, n, "tree", bw, rtt=rtt)
            < collectives.allreduce_duration(small, n, "ring", bw, rtt=rtt))
    assert (collectives.allreduce_duration(large, n, "ring", bw, rtt=rtt)
            < collectives.allreduce_duration(large, n, "tree", bw, rtt=rtt))


def test_collective_rate_throttled_by_topology():
    """A slow tx NIC on one ring member throttles the whole lockstep
    ring; rack oversubscription throttles crossing flows."""
    from repro.core.topology import Node
    flat = Topology.star(4, 1)
    assert collectives.ring_rate_factor(flat, 4) == pytest.approx(1.0)
    slow = Topology(workers=(Node("w0", nic_tx=0.25), Node("w1"),
                             Node("w2"), Node("w3")),
                    ps_nodes=flat.ps_nodes)
    assert collectives.ring_rate_factor(slow, 4) == pytest.approx(0.25)
    racked = Topology.racked(4, 1, racks=2, oversubscription=8.0)
    assert collectives.ring_rate_factor(racked, 4) < 1.0


def test_allreduce_transform_shape():
    """The transformed DAG has no PS resources: downlinks and parse
    overhead vanish, each uplink becomes a collective phase, each update
    becomes a local apply."""
    rng = random.Random(3)
    tpls = make_steps(rng, 1)
    out = allreduce_templates(tpls, 4, bandwidth=BW, rtt=1e-3)
    assert len(out) == len(tpls)
    for src, tpl in zip(tpls, out):
        ress = {op.res for op in tpl.ops}
        assert not any(r.startswith(("downlink", "uplink", "ps"))
                       for r in ress)
        up_sizes = [op.size for op in src.ops
                    if op.res.startswith("uplink")]
        coll_durs = [op.duration for op in tpl.ops
                     if op.res == "collective"]
        assert len(coll_durs) == len(up_sizes)
        for size, dur in zip(up_sizes, coll_durs):
            assert dur == pytest.approx(collectives.allreduce_duration(
                size, 4, "ring", BW, rtt=1e-3))
            assert dur > 0


def test_allreduce_end_to_end_beats_ps_when_bandwidth_bound():
    """A bandwidth-bound PS job re-simulated as ring all-reduce moves
    less data per worker and gets faster; staleness is identically 0."""
    ops = []
    for i in range(3):
        ops.append(Op(f"dl{i}", "downlink", size=8e6))
        ops.append(Op(f"fwd{i}", "worker", duration=0.002,
                      deps=(len(ops) - 1,)))
    for i in range(3):
        ops.append(Op(f"ul{i}", "uplink", size=8e6, deps=(5,)))
        ops.append(Op(f"upd{i}", "ps", duration=0.001,
                      deps=(len(ops) - 1,)))
    tpl = StepTemplate(ops=ops)
    W = 4
    kw = sim_kw(service_jitter=0.0, stall_alpha=0.0, stall_rtt=0.0)
    ps_trace = Simulation(SimConfig(**kw)).run([tpl], W, sample=False)
    ar_tpls = allreduce_templates([tpl], W, bandwidth=BW)
    ar_cfg = SimConfig(sync_mode="allreduce", **kw)
    ar_trace = Simulation(ar_cfg).run(ar_tpls, W, sample=False)
    assert ar_trace.meta["sim_end_time"] < ps_trace.meta["sim_end_time"]
    assert ar_trace.staleness_stats()["max"] == 0
    assert ar_trace.meta["num_versions"] == 20   # one commit per step


# -------------------------------------------------------------- staleness


def test_staleness_stats_shapes():
    assert staleness_stats([])["n"] == 0
    st = staleness_stats([0, 0, 1, 2, 10])
    assert st["n"] == 5 and st["max"] == 10 and st["mean"] == 2.6
    rng = random.Random(11)
    tpls = make_steps(rng, 1)
    tr = Simulation(SimConfig(sync_mode="async", **sim_kw())).run(tpls, 3)
    assert len(tr.staleness) == len(tr.step_completions)
    assert tr.staleness_stats()["mean"] > 0   # W=3 async: real contention


# ------------------------------------------- emulator barrier vs prediction


class TestEmulatorAgainstPrediction:
    """The ClusterEmulator's barrier semantics must agree with the DES
    prediction (the PR-3 straggler-validation pattern: compare regime
    ratios under one measurement convention)."""

    def _run(self, mode, **kw):
        from repro.core.predictor import PredictionRun
        return PredictionRun(dnn="alexnet", batch_size=8,
                             platform="private_cpu", profile_steps=12,
                             sim_steps=80, sync_mode=mode, **kw)

    def test_sync_ratio_matches_emulator(self):
        base = self._run("async").prepare()
        sync = self._run("sync")
        sync.profile = base.profile
        sync.overhead = base.overhead
        sync.sim_steps_templates = base.sim_steps_templates
        pred_ratio = (sync.predict(2, n_runs=1)
                      / base.predict(2, n_runs=1))
        meas_ratio = (sync.measure(2, steps=40)
                      / base.measure(2, steps=40))
        assert pred_ratio == pytest.approx(meas_ratio, rel=0.25)
        # the barrier can only cost throughput
        assert pred_ratio <= 1.05

    def test_allreduce_emulator_runs_collective_dag(self):
        from repro.core.paper_models import PAPER_DNNS, PLATFORMS
        from repro.emulator.cluster import ClusterEmulator
        emu = ClusterEmulator(PAPER_DNNS["alexnet"], 8,
                              PLATFORMS["private_cpu"], num_workers=2,
                              seed=3, sync=SyncSpec(mode="allreduce"))
        emu.run(steps_per_worker=15)
        assert emu.throughput(warmup_steps=5) > 0
        assert emu.staleness_stats()["max"] == 0
        assert any(op.res == "collective" for op in emu.ops)
        assert not any(op.res.startswith(("downlink", "uplink", "ps"))
                       for op in emu.ops)

    def test_emulator_backup_workers_validated(self):
        from repro.core.paper_models import PAPER_DNNS, PLATFORMS
        from repro.emulator.cluster import ClusterEmulator
        with pytest.raises(ValueError, match="quorum"):
            ClusterEmulator(PAPER_DNNS["alexnet"], 8,
                            PLATFORMS["private_cpu"], num_workers=2,
                            sync=SyncSpec(mode="sync", backup_workers=2))
