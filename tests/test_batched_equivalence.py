"""Differential tests: batched lockstep engine vs the scalar simulator.

``repro.core.batched`` promises *bit-identical* traces to running each
scenario through ``Simulation(cfg).run(...)`` one at a time — same step
completion tuples, same staleness sequence, same event/version counters,
same end time — with scalar fallback (never wrong answers) for anything
outside the batchable regime.  These tests drive both engines over
(W, seed, link-policy, fault, sync-mode) matrices and compare exactly.

Set ``REPRO_BATCHED_FULL=1`` (the nightly job does) to widen every seed
matrix; the default sizes keep the suite PR-fast.
"""
import dataclasses
import os
import random

import pytest

from repro.core.batched import (Scenario, _BatchedMT, classify,
                                run_scenarios)
from repro.core.events import Op, StepTemplate, ps_resources
from repro.core.faults import FaultSpec
from repro.core.simulator import SimConfig, Simulation

FULL = bool(os.environ.get("REPRO_BATCHED_FULL"))
NSEEDS = 12 if FULL else 4


def make_template(layers, seed=0, num_ps=1):
    """PS-training-shaped step (download -> fwd; bwd -> upload per layer),
    the same synthetic workload shape the perf benchmark batches."""
    rng = random.Random(seed)

    def link(kind, i):
        return kind if num_ps == 1 else f"{kind}:{i % num_ps}"

    ops = []
    fwd_prev = None
    for i in range(layers):
        dl = len(ops)
        ops.append(Op(f"dl{i}", link("downlink", i),
                      size=rng.uniform(2e6, 3e7)))
        deps = (dl,) if fwd_prev is None else (dl, fwd_prev)
        fwd_prev = len(ops)
        ops.append(Op(f"fwd{i}", "worker", duration=rng.uniform(.005, .05),
                      deps=deps))
    bwd_prev = fwd_prev
    for i in reversed(range(layers)):
        bwd = len(ops)
        ops.append(Op(f"bwd{i}", "worker", duration=rng.uniform(.01, .08),
                      deps=(bwd_prev,)))
        bwd_prev = bwd
        ops.append(Op(f"ul{i}", link("uplink", i),
                      size=rng.uniform(2e6, 3e7), deps=(bwd,)))
    return StepTemplate(ops=ops)


def make_cfg(steps_per_worker, seed=0, num_ps=1, **kw):
    return SimConfig(resources=ps_resources(1e9, num_ps),
                     link_policy="http2", win=2.8e6,
                     steps_per_worker=steps_per_worker, warmup_steps=2,
                     seed=seed, service_jitter=0.08,
                     stall_alpha=2e-9, stall_rtt=5e-4, **kw)


TPLS = [make_template(3, seed=0)]
TPLS2 = [make_template(3, seed=0), make_template(4, seed=1)]
TPLS_PS2 = [make_template(3, seed=0, num_ps=2),
            make_template(4, seed=1, num_ps=2)]


def fingerprint(tr):
    return (tr.step_completions, tr.staleness, tr.meta["sim_end_time"],
            tr.meta["num_events"], tr.meta["num_versions"])


def assert_equivalent(scens):
    """Batched output must be bit-identical to per-scenario scalar runs."""
    traces = run_scenarios(scens, engine="auto", min_batch=1)
    for sc, tr in zip(scens, traces):
        ref = Simulation(sc.cfg).run(sc.steps, sc.num_workers,
                                     sample=sc.sample)
        assert fingerprint(tr) == fingerprint(ref), (
            f"engine={tr.meta.get('engine')} "
            f"fallback={tr.meta.get('batch_fallback')} "
            f"W={sc.num_workers} seed={sc.cfg.seed}")
    return traces


FAMILIES = {
    "smoke_w4": lambda: [Scenario(make_cfg(6, seed=s), TPLS, 4)
                         for s in range(NSEEDS)],
    "w8_ps2_2tpl": lambda: [Scenario(make_cfg(5, seed=s, num_ps=2),
                                     TPLS_PS2, 8) for s in range(NSEEDS)],
    "mixed_w": lambda: [Scenario(make_cfg(4, seed=s), TPLS2, 1 + (s % 8))
                        for s in range(2 * NSEEDS)],
    "fifo": lambda: [Scenario(dataclasses.replace(make_cfg(5, seed=s),
                                                  link_policy="fifo"),
                              TPLS, 4) for s in range(NSEEDS)],
    "stall0": lambda: [Scenario(dataclasses.replace(make_cfg(5, seed=s),
                                                    stall_alpha=0.0,
                                                    stall_rtt=0.0),
                                TPLS, 4) for s in range(NSEEDS)],
    "jitter0": lambda: [Scenario(dataclasses.replace(make_cfg(4, seed=s),
                                                     service_jitter=0.0),
                                 TPLS, 3) for s in range(NSEEDS)],
    "cycle": lambda: [Scenario(make_cfg(5, seed=s), TPLS2, 4, sample=False)
                      for s in range(NSEEDS)],
}


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_batched_matches_scalar(family):
    assert_equivalent(FAMILIES[family]())


def test_batched_engine_actually_used():
    """The differential suite must not be vacuous: the homogeneous smoke
    family has to take the lockstep path for (at least most of) its
    members, not silently fall back to scalar-vs-scalar."""
    traces = assert_equivalent(FAMILIES["smoke_w4"]())
    batched = [t for t in traces if t.meta["engine"] == "batched"]
    assert len(batched) >= len(traces) // 2, (
        [t.meta.get("batch_fallback") for t in traces])


def test_unbatchable_configs_fall_back_and_match():
    """Sync/SSP modes and fault injection run scalar — with the reason
    recorded — and still return the exact scalar trace."""
    faults = FaultSpec(mttf=40.0, mttr=5.0)
    scens = [Scenario(make_cfg(5, seed=s, sync_mode="sync"), TPLS, 4)
             for s in range(2)]
    scens += [Scenario(make_cfg(5, seed=s, sync_mode="ssp",
                                staleness_bound=2), TPLS, 4)
              for s in range(2)]
    scens += [Scenario(make_cfg(5, seed=s, faults=faults), TPLS, 4)
              for s in range(2)]
    traces = assert_equivalent(scens)
    assert all(t.meta["engine"] == "scalar" for t in traces)
    reasons = [t.meta["batch_fallback"] for t in traces]
    assert any("sync_mode" in r for r in reasons)
    assert any("fault" in r for r in reasons)


def test_forced_scalar_engine():
    scens = [Scenario(make_cfg(4, seed=s), TPLS, 2) for s in range(3)]
    traces = run_scenarios(scens, engine="scalar")
    assert all(t.meta["engine"] == "scalar" for t in traces)
    assert all(t.meta["batch_fallback"] == "forced scalar" for t in traces)


def test_unknown_engine_raises():
    with pytest.raises(ValueError, match="unknown engine"):
        run_scenarios([Scenario(make_cfg(3), TPLS, 2)], engine="turbo")


def test_small_group_falls_back():
    (tr,) = run_scenarios([Scenario(make_cfg(4, seed=0), TPLS, 2)],
                          engine="auto", min_batch=2)
    assert tr.meta["engine"] == "scalar"
    assert "min_batch" in tr.meta["batch_fallback"]


def test_classify_reasons():
    cfg = make_cfg(4, seed=0)
    assert classify(cfg, 4) is None
    cases = [
        (dataclasses.replace(cfg, sync_mode="sync"), 4, "sync_mode"),
        (dataclasses.replace(cfg, faults=FaultSpec(mttf=10.0, mttr=1.0)),
         4, "fault"),
        (dataclasses.replace(cfg, link_policy="ordered"), 4,
         "link_policy"),
        (dataclasses.replace(cfg, record_trace=True), 4, "trace"),
        (dataclasses.replace(cfg, worker_speed={0: 2.0}), 4,
         "heterogeneous"),
        (dataclasses.replace(cfg, seed=None), 4, "unseeded"),
        (cfg, 0, "num_workers"),
    ]
    for c, w, substr in cases:
        reason = classify(c, w)
        assert reason is not None and substr in reason, (substr, reason)
    # an empty FaultSpec is equivalent to no faults at all
    assert classify(dataclasses.replace(cfg, faults=FaultSpec()), 4) is None


def test_batched_mt_matches_cpython_key_schedule():
    """Row b of the vectorized seeder must equal CPython's MT state for
    seed b — both the fast int path and the getstate() fallback."""
    seeds = [0, 1, 7, 123456, 2 ** 32 - 1]
    mt = _BatchedMT(seeds)
    for b, s in enumerate(seeds):
        ref = random.Random(s).getstate()[1][:624]
        assert mt.key[b].tolist() == list(ref), f"seed {s}"
    # non-word seeds route through random.Random.getstate()
    big = [2 ** 40 + 3, -5]
    mt = _BatchedMT(big)
    for b, s in enumerate(big):
        ref = random.Random(s).getstate()[1][:624]
        assert mt.key[b].tolist() == list(ref), f"seed {s}"


def test_fallback_reason_categories():
    """Every scalar fallback stamps ``meta["batch_fallback_reason"]`` with
    its machine-readable category alongside the free-text reason."""
    faults = FaultSpec(mttf=40.0, mttr=5.0)
    cases = [
        (Scenario(make_cfg(5, seed=0, sync_mode="sync"), TPLS, 4),
         "barrier"),
        (Scenario(make_cfg(5, seed=0, faults=faults), TPLS, 4), "faults"),
        (Scenario(make_cfg(5, seed=0,
                           worker_speed={0: 2.0}), TPLS, 4), "hetero"),
        (Scenario(dataclasses.replace(make_cfg(5, seed=0),
                                      link_policy="ordered"), TPLS, 4),
         "policy"),
        (Scenario(make_cfg(5, seed=0, record_trace=True), TPLS, 4),
         "trace"),
    ]
    traces = run_scenarios([sc for sc, _cat in cases], engine="auto")
    for tr, (_sc, cat) in zip(traces, cases):
        assert tr.meta["engine"] == "scalar"
        assert tr.meta["batch_fallback_reason"] == cat, (
            cat, tr.meta["batch_fallback"])


def test_forced_scalar_fallback_category():
    (tr,) = run_scenarios([Scenario(make_cfg(4, seed=0), TPLS, 2)],
                          engine="scalar")
    assert tr.meta["batch_fallback_reason"] == "forced"
