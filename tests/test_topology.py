"""Topology & placement layer: validation, compilation, threading.

Covers: input validation with clear errors (Topology, SimConfig), star
compilation equivalence with the paper's resource factory and bandwidth
rules, compute speed factors, and the qualitative effects the layer exists
to capture — oversubscribed rack fabrics throttle scale-out, and a PS
colocated with a worker moves the bottleneck onto the shared NIC.
"""
import math

import pytest

from repro.core.bandwidth import BandwidthModel, EqualShareModel
from repro.core.events import Op, StepTemplate, ps_resources
from repro.core.simulator import SimConfig, Simulation
from repro.core.topology import (Node, Placement, Rack, Topology,
                                 TopologyBandwidthModel)

BW = 1e2  # bytes/s, easy arithmetic


def comm_heavy_steps(n_layers=4, size=200.0, compute=0.05, num_ps=1):
    """Uplink/downlink-dominated step (bandwidth-bound regime); layers
    round-robin over ``num_ps`` shards."""
    ops = []
    for i in range(n_layers):
        p = i % num_ps
        dn = "downlink" if num_ps == 1 else f"downlink:{p}"
        up = "uplink" if num_ps == 1 else f"uplink:{p}"
        dl = len(ops)
        ops.append(Op(f"d{i}", dn, size=size))
        ops.append(Op(f"f{i}", "worker", duration=compute, deps=(dl,)))
        ops.append(Op(f"u{i}", up, size=size, deps=(dl + 1,)))
    return [StepTemplate(ops=ops)]


def run_tput(topology, workers, steps=None, steps_per_worker=30,
             policy="fifo", **cfg_kw):
    cfg = SimConfig(topology=topology, link_policy=policy,
                    steps_per_worker=steps_per_worker, warmup_steps=5,
                    **cfg_kw)
    tr = Simulation(cfg).run(steps or comm_heavy_steps(), workers,
                             sample=False)
    return tr.throughput(32, warmup_steps=5)


class TestValidation:
    def test_needs_workers(self):
        with pytest.raises(ValueError, match="at least one worker"):
            Topology(workers=(), ps_nodes=(Node("ps0"),))

    def test_unplaced_ps(self):
        with pytest.raises(ValueError, match="unplaced parameter servers"):
            Topology(workers=(Node("w0"),))

    def test_unknown_placement_node(self):
        with pytest.raises(ValueError, match="unknown node 'nope'"):
            Topology(workers=(Node("w0"),), ps_nodes=(Node("ps0"),),
                     placement=Placement(("nope",)))

    def test_unknown_rack(self):
        with pytest.raises(ValueError, match="unknown rack"):
            Topology(workers=(Node("w0", rack="r9"),),
                     ps_nodes=(Node("ps0"),))

    def test_duplicate_node_name(self):
        with pytest.raises(ValueError, match="duplicate node name"):
            Topology(workers=(Node("x"), Node("x")), ps_nodes=(Node("ps0"),))

    def test_oversubscription_below_one(self):
        with pytest.raises(ValueError, match="oversubscription must be >= 1"):
            Rack("r0", oversubscription=0.5)

    def test_bad_nic_and_speed(self):
        with pytest.raises(ValueError, match="nic capacity must be > 0"):
            Node("w0", nic=-1.0)
        with pytest.raises(ValueError, match="speed must be > 0"):
            Node("w0", speed=0.0)

    def test_negative_bandwidth(self):
        with pytest.raises(ValueError, match="bandwidth must be > 0"):
            Topology.star(2, 1, bandwidth=-5.0)

    def test_resources_need_bandwidth(self):
        with pytest.raises(ValueError, match="no nominal bandwidth"):
            Topology.star(2, 1).resources()

    def test_empty_placement(self):
        with pytest.raises(ValueError, match="at least one PS shard"):
            Placement(())


class TestSimConfigValidation:
    def test_needs_resources_or_topology(self):
        with pytest.raises(ValueError, match="resources= or topology="):
            SimConfig()

    def test_zero_win(self):
        with pytest.raises(ValueError, match="window must be > 0"):
            SimConfig(resources=ps_resources(BW), win=0.0)

    def test_bad_policy(self):
        with pytest.raises(ValueError, match="unknown link_policy"):
            SimConfig(resources=ps_resources(BW), link_policy="tcp")

    def test_bad_steps(self):
        with pytest.raises(ValueError, match="steps_per_worker"):
            SimConfig(resources=ps_resources(BW), steps_per_worker=0)

    def test_negative_jitter(self):
        with pytest.raises(ValueError, match="service_jitter"):
            SimConfig(resources=ps_resources(BW), service_jitter=-0.1)

    def test_too_many_workers_for_topology(self):
        cfg = SimConfig(topology=Topology.star(2, 1, bandwidth=BW))
        with pytest.raises(ValueError, match="only 2 worker nodes"):
            Simulation(cfg).run(comm_heavy_steps(), 3, sample=False)

    def test_link_bandwidth_still_validated(self):
        with pytest.raises(ValueError, match="bandwidth > 0"):
            ps_resources(0.0)

    def test_resources_topology_shard_mismatch(self):
        """Explicit resources that don't name the topology's links would
        make every compiled capacity group silently match nothing."""
        with pytest.raises(ValueError, match="missing link 'downlink:0'"):
            SimConfig(resources=ps_resources(BW, 1),
                      topology=Topology.racked(4, 2, oversubscription=8.0,
                                               bandwidth=BW))


class TestStarCompilation:
    def test_resources_match_ps_resources(self):
        for m in (1, 2, 3):
            t = Topology.star(4, m, bandwidth=BW)
            assert t.resources() == ps_resources(BW, m)
            assert list(t.resources()) == list(ps_resources(BW, m))

    def test_bandwidth_model_defaults(self):
        assert type(Topology.star(4, 1).bandwidth_model()) is EqualShareModel
        assert type(Topology.star(4, 2).bandwidth_model()) is BandwidthModel
        t = Topology.racked(4, 2, oversubscription=2.0)
        assert isinstance(t.bandwidth_model(), TopologyBandwidthModel)

    def test_grouped_model_reduces_to_paper_rules(self):
        gm = Topology.star(6, 2).grouped_model()
        bm = BandwidthModel()
        cases = [
            {"downlink:0": {0, 1, 2}},
            {"downlink:0": {0}, "downlink:1": {0, 1, 2, 3}},
            {"downlink:0": {0, 1}, "uplink:0": {1, 2}, "uplink:1": {0}},
        ]
        for active in cases:
            assert gm.shares(active) == bm.shares(active)

    def test_star_sim_equals_default_sim(self):
        """Topology.star() threading end-to-end: identical trace to the
        plain resources= path (same engine path, same RNG draws)."""
        tpls = comm_heavy_steps()
        kw = dict(link_policy="http2", win=150.0, steps_per_worker=20,
                  warmup_steps=5, seed=3, service_jitter=0.1,
                  record_trace=True)
        a = Simulation(SimConfig(resources=ps_resources(BW), **kw)).run(
            tpls, 3)
        b = Simulation(SimConfig(topology=Topology.star(3, 1, bandwidth=BW),
                                 **kw)).run(tpls, 3)
        assert a.step_completions == b.step_completions
        assert [(r.worker, r.name, r.end) for r in a.records] == \
               [(r.worker, r.name, r.end) for r in b.records]


class TestSpeedFactors:
    def test_slow_worker_scales_compute(self):
        ops = [Op("d", "downlink", size=200),
               Op("f", "worker", duration=1.0, deps=(0,)),
               Op("u", "uplink", size=100, deps=(1,))]
        fast = Topology(workers=(Node("w0"),), ps_nodes=(Node("ps0"),),
                        bandwidth=BW)
        slow = Topology(workers=(Node("w0", speed=0.5),),
                        ps_nodes=(Node("ps0"),), bandwidth=BW)
        t_fast = run_tput(fast, 1, steps=[StepTemplate(ops=list(ops))],
                          steps_per_worker=1)
        t_slow = run_tput(slow, 1, steps=[StepTemplate(ops=list(ops))],
                          steps_per_worker=1)
        # serial chain 2 + 1 + 1 = 4s vs 2 + 2 + 1 = 5s
        assert t_fast == pytest.approx(t_slow * 5.0 / 4.0)

    def test_slow_ps_scales_update(self):
        ops = [Op("u", "uplink", size=100),
               Op("upd", "ps", duration=1.0, deps=(0,))]
        base = Topology(workers=(Node("w0"),), ps_nodes=(Node("ps0"),),
                        bandwidth=BW)
        slow = Topology(workers=(Node("w0"),),
                        ps_nodes=(Node("ps0", speed=0.25),), bandwidth=BW)
        cfg_b = SimConfig(topology=base, link_policy="fifo",
                          steps_per_worker=1, warmup_steps=0,
                          record_op_times=True)
        cfg_s = SimConfig(topology=slow, link_policy="fifo",
                          steps_per_worker=1, warmup_steps=0,
                          record_op_times=True)
        tb = Simulation(cfg_b).run([StepTemplate(ops=list(ops))], 1,
                                   sample=False)
        ts = Simulation(cfg_s).run([StepTemplate(ops=list(ops))], 1,
                                   sample=False)
        assert tb.step_completions[0][2] == pytest.approx(2.0)
        assert ts.step_completions[0][2] == pytest.approx(5.0)  # 1 + 4


class TestQualitativeEffects:
    """The two headline behaviors the ISSUE's benchmark must show."""

    def _ps_rack(self, num_workers, ratio):
        """Both PS shards isolated in rack r0; workers in rack r1.  All
        PS traffic crosses r0's (oversubscribed) uplink."""
        return Topology(
            workers=tuple(Node(f"w{i}", rack="r1")
                          for i in range(num_workers)),
            ps_nodes=(Node("ps0", rack="r0"), Node("ps1", rack="r0")),
            racks=(Rack("r0", oversubscription=ratio), Rack("r1")),
            bandwidth=BW)

    def test_oversubscription_throttles(self):
        steps = comm_heavy_steps(num_ps=2)
        flat = run_tput(self._ps_rack(4, 1.0), 4, steps=steps)
        tight = run_tput(self._ps_rack(4, 8.0), 4, steps=steps)
        assert tight < 0.9 * flat

    def test_oversubscription_monotone(self):
        steps = comm_heavy_steps(num_ps=2)
        prev = math.inf
        for ratio in (1.0, 4.0, 16.0):
            cur = run_tput(self._ps_rack(4, ratio), 4, steps=steps)
            assert cur <= prev + 1e-9
            prev = cur

    def test_colocated_ps_shares_host_nic(self):
        dedicated = Topology(
            workers=tuple(Node(f"w{i}") for i in range(4)),
            ps_nodes=(Node("ps0"),), bandwidth=BW)
        colocated = Topology(
            workers=tuple(Node(f"w{i}") for i in range(4)),
            placement=Placement(("w0",)), bandwidth=BW)
        t_ded = run_tput(dedicated, 4)
        t_col = run_tput(colocated, 4)
        # host NIC now carries the PS's fan-in/out AND w0's own transfers
        assert t_col < t_ded

    def test_hetero_ps_nic_helps(self):
        slow_ps = Topology(
            workers=tuple(Node(f"w{i}") for i in range(6)),
            ps_nodes=(Node("ps0", nic=1.0),), bandwidth=BW)
        fast_ps = Topology(
            workers=tuple(Node(f"w{i}") for i in range(6)),
            ps_nodes=(Node("ps0", nic=3.0),), bandwidth=BW)
        assert run_tput(fast_ps, 6) > 1.2 * run_tput(slow_ps, 6)


class TestEmulatorFabric:
    def test_star_topology_close_to_classic(self):
        from repro.core.paper_models import PAPER_DNNS, PLATFORMS
        from repro.emulator.cluster import measure_throughput
        dnn, plat = PAPER_DNNS["googlenet"], PLATFORMS["private_cpu"]
        classic = measure_throughput(dnn, 16, plat, num_workers=3,
                                     num_ps=2, steps=30, seed=0)
        fabric = measure_throughput(dnn, 16, plat, num_workers=3, steps=30,
                                    seed=0, topology=Topology.star(3, 2))
        # same fluid semantics; fabric adds NIC coupling the independent
        # per-link clocks ignore, so allow a small gap
        assert fabric == pytest.approx(classic, rel=0.1)

    def test_emulator_oversubscription_throttles(self):
        from repro.core.paper_models import PAPER_DNNS, PLATFORMS
        from repro.emulator.cluster import measure_throughput
        dnn, plat = PAPER_DNNS["alexnet"], PLATFORMS["private_cpu"]

        def topo(ratio):
            return Topology(
                workers=tuple(Node(f"w{i}", rack="r1") for i in range(4)),
                ps_nodes=(Node("ps0", rack="r0"),),
                racks=(Rack("r0", oversubscription=ratio), Rack("r1")))
        flat = measure_throughput(dnn, 8, plat, num_workers=4, steps=30,
                                  seed=0, topology=topo(1.0))
        tight = measure_throughput(dnn, 8, plat, num_workers=4, steps=30,
                                   seed=0, topology=topo(8.0))
        assert tight < flat

    def test_emulator_rejects_excess_workers(self):
        from repro.core.paper_models import PAPER_DNNS, PLATFORMS
        from repro.emulator.cluster import ClusterEmulator
        with pytest.raises(ValueError, match="only 2 worker nodes"):
            ClusterEmulator(PAPER_DNNS["googlenet"], 16,
                            PLATFORMS["private_cpu"], num_workers=3,
                            topology=Topology.star(2, 1))

    def test_emulator_rejects_num_ps_conflict(self):
        """Same contract as PredictionRun: an explicit num_ps that
        disagrees with the topology is an error, not a silent override."""
        from repro.core.paper_models import PAPER_DNNS, PLATFORMS
        from repro.emulator.cluster import ClusterEmulator
        with pytest.raises(ValueError, match="conflicts with topology"):
            ClusterEmulator(PAPER_DNNS["googlenet"], 16,
                            PLATFORMS["private_cpu"], num_workers=2,
                            num_ps=4, topology=Topology.star(4, 2))


class TestPredictionRunThreading:
    def test_num_ps_follows_topology(self):
        from repro.core.predictor import PredictionRun
        r = PredictionRun(dnn="googlenet", batch_size=16,
                          platform="private_cpu",
                          topology=Topology.star(4, 2))
        assert r.num_ps == 2

    def test_num_ps_conflict_rejected(self):
        from repro.core.predictor import PredictionRun
        with pytest.raises(ValueError, match="conflicts with topology"):
            PredictionRun(dnn="googlenet", batch_size=16,
                          platform="private_cpu", num_ps=3,
                          topology=Topology.star(4, 2))

    def test_with_topology_shard_mismatch_rejected(self):
        """A prepared run's profile is bound to its per-shard links;
        attaching a topology with a different shard count must fail loudly
        instead of KeyError-ing deep inside the simulator."""
        from repro.core.predictor import PredictionRun
        r = PredictionRun(dnn="googlenet", batch_size=16,
                          platform="private_cpu", num_ps=1)
        with pytest.raises(ValueError, match="matching num_ps"):
            r.with_topology(Topology.star(4, 2))

    def test_topology_bandwidth_beats_platform_default(self):
        """Explicit Topology.bandwidth must drive the compiled resources
        (same precedence as the emulator) so predictions and ground truth
        describe the same cluster."""
        t = Topology.star(2, 1, bandwidth=5e6)
        res = t.resources(default_bandwidth=1e9)
        assert res["downlink"].bandwidth == 5e6
        res2 = Topology.star(2, 1).resources(default_bandwidth=1e9)
        assert res2["downlink"].bandwidth == 1e9


class TestAsymmetricNics:
    """Per-direction NIC capacities (Node.nic_tx / nic_rx) in the group
    compiler: uplink conns ride the worker's tx port, downlink its rx."""

    def test_defaults_to_symmetric_nic(self):
        n = Node("w0", nic=2.0)
        assert n.tx == 2.0 and n.rx == 2.0
        n = Node("w0", nic=2.0, nic_tx=0.5)
        assert n.tx == 0.5 and n.rx == 2.0

    def test_validation(self):
        with pytest.raises(ValueError, match="nic_tx"):
            Node("w0", nic_tx=0.0)
        with pytest.raises(ValueError, match="nic_rx"):
            Node("w0", nic_rx=-1.0)

    def test_asym_worker_port_splits_directions(self):
        topo = Topology(workers=(Node("w0", nic_tx=0.5, nic_rx=2.0),
                                 Node("w1")),
                        ps_nodes=(Node("ps0", nic=4.0),))
        sh = topo.grouped_model().shares({"uplink": {0}, "downlink": {0}})
        assert sh[(0, "uplink")] == pytest.approx(0.5)    # tx-capped
        assert sh[(0, "downlink")] == pytest.approx(2.0)  # rx-capped

    def test_asym_ps_port_caps_links_per_direction(self):
        # downlink = PS transmits (tx); uplink = PS receives (rx)
        topo = Topology(workers=(Node("w0", nic=8.0), Node("w1", nic=8.0)),
                        ps_nodes=(Node("ps0", nic_tx=0.5, nic_rx=2.0),))
        m = topo.grouped_model()
        sh = m.shares({"downlink": {0, 1}, "uplink": {0, 1}})
        assert sh[(0, "downlink")] + sh[(1, "downlink")] == \
            pytest.approx(0.5)
        assert sh[(0, "uplink")] + sh[(1, "uplink")] == pytest.approx(2.0)

    def test_asym_breaks_plain_star(self):
        assert Topology.star(2, 1).is_plain_star()
        t = Topology(workers=(Node("w0", nic_tx=2.0), Node("w1")),
                     ps_nodes=(Node("ps0"),))
        assert not t.is_plain_star()

    def test_rack_caps_aggregate_per_direction(self):
        t = Topology(
            workers=(Node("w0", rack="r0", nic_tx=2.0, nic_rx=1.0),
                     Node("w1", rack="r0")),
            ps_nodes=(Node("ps0"),),
            racks=(Rack("r0", oversubscription=2.0),))
        caps = t.rack_uplink_caps()
        assert caps["r0"] == (pytest.approx(1.5), pytest.approx(1.0))


class TestLoopbackBypass:
    """Colocated-shard localhost transfers skip the NIC groups when the
    bypass flag is on (ROADMAP open item)."""

    def _colocated(self, bypass):
        return Topology(workers=(Node("w0"), Node("w1"), Node("w2")),
                        placement=Placement(("w0",)),
                        loopback_bypass=bypass)

    def test_loopback_conns_only_with_flag(self):
        assert self._colocated(False).loopback_conns() == set()
        assert self._colocated(True).loopback_conns() == {
            (0, "downlink"), (0, "uplink")}

    def test_bypass_frees_the_host_nic(self):
        active = {"downlink": {0, 1, 2}, "uplink": {0}}
        sh_cons = self._colocated(False).grouped_model().shares(active)
        sh_by = self._colocated(True).grouped_model().shares(active)
        # loopback conns leave the shared NIC group entirely...
        assert sh_by[(0, "downlink")] > 1.0
        assert sh_by[(0, "uplink")] > 1.0
        # ...and the remote workers' shares rise to the freed capacity
        assert sh_by[(1, "downlink")] > sh_cons[(1, "downlink")]
        assert sum(sh_by[(w, "downlink")] for w in (1, 2)) == \
            pytest.approx(1.0)

    def test_bypass_is_noop_without_colocation(self):
        star = Topology.star(3, 1)
        with_flag = Topology(workers=star.workers, ps_nodes=star.ps_nodes,
                             loopback_bypass=True)
        assert with_flag.loopback_conns() == set()
        active = {"downlink": {0, 1, 2}}
        assert with_flag.bandwidth_model().shares(active) == \
            star.bandwidth_model().shares(active)

    def test_bypass_improves_end_to_end_makespan(self):
        tpl = StepTemplate(ops=[
            Op("dl", "downlink", size=60.0),
            Op("fwd", "worker", duration=0.05, deps=(0,)),
            Op("ul", "uplink", size=60.0, deps=(1,)),
            Op("upd", "ps", duration=0.01, deps=(2,)),
        ])

        def makespan(bypass):
            topo = self._colocated(bypass)
            cfg = SimConfig(resources=topo.resources(BW), topology=topo,
                            steps_per_worker=40, warmup_steps=5, seed=0)
            tr = Simulation(cfg).run([tpl], 3, sample=False)
            return tr.meta["sim_end_time"]

        # the colocated worker's transfers leave the shared NIC, so the
        # same fixed step budget finishes sooner for everyone
        assert makespan(True) < makespan(False)

    def test_loopback_capacity_validated(self):
        with pytest.raises(ValueError, match="loopback_capacity"):
            Topology(workers=(Node("w0"),), placement=Placement(("w0",)),
                     loopback_capacity=0.0)
