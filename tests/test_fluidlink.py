"""Shared fluid-link kernel (virtual-service clocks), used by both the DES
engine (equal-share specialization) and the cluster emulator (weighted)."""
import math

import pytest

from repro.core.fluidlink import EqualShareLink, Flow, WeightedFluidLink


class TestWeightedFluidLink:
    def test_single_flow_full_rate(self):
        link = WeightedFluidLink(100.0)
        done = []
        link.add_flow(0.0, Flow(fid=1, weight=1.0, remaining=200.0,
                                on_complete=lambda: done.append(1)))
        assert link.next_projection(0.0) == pytest.approx(2.0)

    def test_weighted_sharing(self):
        """Weights 1 and 3 on a 100 B/s link: rates 25 and 75."""
        link = WeightedFluidLink(100.0)
        link.add_flow(0.0, Flow(fid=1, weight=1.0, remaining=100.0))
        link.add_flow(0.0, Flow(fid=2, weight=3.0, remaining=300.0))
        # both complete simultaneously at t = 4 (same per-weight service)
        assert link.next_projection(0.0) == pytest.approx(4.0)
        flows = link.pop_due(4.0)
        assert {f.fid for f in flows} == {1, 2}
        assert link.total_w == 0.0

    def test_rate_change_preserves_targets(self):
        """A second flow joining mid-service only stretches real time; the
        virtual target is untouched (the whole point of the clock)."""
        link = WeightedFluidLink(100.0)
        link.add_flow(0.0, Flow(fid=1, weight=1.0, remaining=100.0))
        # after 0.5s, 50 bytes served; a peer joins, rate halves
        link.add_flow(0.5, Flow(fid=2, weight=1.0, remaining=1000.0))
        # remaining 50 bytes at 50 B/s -> completes at t = 1.5
        assert link.next_projection(0.5) == pytest.approx(1.5)
        done = link.pop_due(1.5)
        assert [f.fid for f in done] == [1]

    def test_remove_flow_lazy_heap(self):
        link = WeightedFluidLink(100.0)
        f1 = Flow(fid=1, weight=1.0, remaining=100.0)
        link.add_flow(0.0, f1)
        link.add_flow(0.0, Flow(fid=2, weight=1.0, remaining=math.inf))
        link.remove_flow(0.0, 1)
        # heap still holds the stale entry; projection must skip it
        assert link.next_projection(0.0) is None   # only inf flow left
        assert link.total_w == pytest.approx(1.0)

    def test_background_flow_never_projects(self):
        link = WeightedFluidLink(100.0)
        link.add_flow(0.0, Flow(fid=1, weight=1.0, remaining=math.inf))
        assert link.next_projection(0.0) is None

    def test_epoch_bumps_on_membership_change(self):
        link = WeightedFluidLink(100.0)
        e0 = link.epoch
        link.add_flow(0.0, Flow(fid=1, weight=1.0, remaining=10.0))
        assert link.epoch == e0 + 1
        link.remove_flow(0.0, 1)
        assert link.epoch == e0 + 2

    def test_pop_due_bumps_epoch_once(self):
        link = WeightedFluidLink(100.0)
        link.add_flow(0.0, Flow(fid=1, weight=1.0, remaining=50.0))
        link.add_flow(0.0, Flow(fid=2, weight=1.0, remaining=50.0))
        e = link.epoch
        done = link.pop_due(1.0)
        assert len(done) == 2
        assert link.epoch == e + 1


class TestEqualShareLink:
    def test_clock_materialization(self):
        link = EqualShareLink(100.0)
        link.rate = 25.0
        link.materialize(2.0)
        assert link.V == pytest.approx(50.0)
        # time never runs backwards
        link.materialize(1.0)
        assert link.V == pytest.approx(50.0)

    def test_active_set_slot(self):
        link = EqualShareLink(100.0)
        link.active.add(3)
        assert 3 in link.active
