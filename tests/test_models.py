"""Per-arch smoke tests (reduced configs) + decode/forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (forward, init_decode_state, init_params, loss_fn,
                          param_count, precompute_cross_kv, serve_step)
from repro.optim import make_optimizer

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=16, seed=0):
    k = jax.random.PRNGKey(seed)
    toks = jax.random.randint(k, (b, s + 1), 0, cfg.vocab)
    batch = {"tokens": toks[:, :-1].astype(jnp.int32),
             "labels": toks[:, 1:].astype(jnp.int32)}
    if cfg.encoder_layers:
        batch["frames"] = 0.1 * jax.random.normal(
            k, (b, cfg.encoder_len, cfg.d_model), jnp.dtype(cfg.dtype))
    elif cfg.cross_len:
        batch["enc_embed"] = 0.1 * jax.random.normal(
            k, (b, cfg.cross_len, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


@pytest.fixture(scope="module")
def smoke(request):
    return None


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_config(arch, smoke=True)
        params = init_params(KEY, cfg)
        batch = make_batch(cfg)
        logits, aux = forward(params, batch, cfg)
        assert logits.shape == (2, 16, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        assert np.isfinite(float(aux["aux_loss"]))

    def test_one_train_step_reduces_nothing_nan(self, arch):
        cfg = get_config(arch, smoke=True)
        params = init_params(KEY, cfg)
        batch = make_batch(cfg)
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch, cfg)
        assert np.isfinite(float(loss))
        gnorm = sum(float(jnp.sum(jnp.square(g)))
                    for g in jax.tree_util.tree_leaves(grads))
        assert np.isfinite(gnorm) and gnorm > 0
        opt = make_optimizer("adamw", lr=1e-3)
        st = opt.init(params)
        new_params, _ = opt.update(grads, st, params)
        loss2, _ = loss_fn(new_params, batch, cfg)
        assert np.isfinite(float(loss2))

    def test_decode_matches_forward_teacher_forced(self, arch):
        """Greedy decode state must reproduce forward() logits position by
        position (KV-cache / recurrent-state correctness)."""
        cfg = get_config(arch, smoke=True)
        params = init_params(KEY, cfg)
        b, s = 2, 8
        batch = make_batch(cfg, b=b, s=s)
        full_logits, _ = forward(params, batch, cfg)

        state = init_decode_state(cfg, b, s)
        if cfg.cross_len:
            from repro.models.transformer import _get_encoder_states
            enc = _get_encoder_states(params, batch, cfg)
            state = precompute_cross_kv(
                params, state, enc.astype(cfg.dtype), cfg)
        errs = []
        for i in range(s):
            li, state = serve_step(params, state, batch["tokens"][:, i], cfg)
            errs.append(np.max(np.abs(
                np.asarray(li, np.float32)
                - np.asarray(full_logits[:, i], np.float32))))
        scale = float(np.max(np.abs(np.asarray(full_logits, np.float32))))
        assert max(errs) < 2e-2 * max(scale, 1.0), \
            f"decode/forward divergence {max(errs):.3e} (scale {scale:.1f})"


class TestVocabPadding:
    def test_pad_region_masked(self):
        cfg = get_config("whisper-small", smoke=True)
        assert cfg.padded_vocab % 512 == 0
        params = init_params(KEY, cfg)
        batch = make_batch(cfg)
        logits, _ = forward(params, batch, cfg)
        pad = np.asarray(logits[..., cfg.vocab:], np.float32)
        real = np.asarray(logits[..., : cfg.vocab], np.float32)
        if pad.size:
            assert pad.max() < real.max() - 1e6  # -inf-ish


class TestChunkedAttentionEquivalence:
    def test_forward_naive_vs_chunked(self):
        cfg = get_config("granite-8b", smoke=True).replace(
            attention_impl="naive")
        cfg_c = cfg.replace(attention_impl="chunked", attention_chunk=8)
        params = init_params(KEY, cfg)
        batch = make_batch(cfg, s=32)
        l1, _ = forward(params, batch, cfg)
        l2, _ = forward(params, batch, cfg_c)
        err = np.max(np.abs(np.asarray(l1, np.float32)
                            - np.asarray(l2, np.float32)))
        assert err < 1e-2


class TestParamCounts:
    """Sanity: configured sizes land near their nameplates."""

    @pytest.mark.parametrize("arch,lo,hi", [
        ("gemma-7b", 7e9, 10e9),
        ("granite-8b", 7e9, 9.5e9),
        ("deepseek-moe-16b", 14e9, 19e9),
        ("phi4-mini-3.8b", 3.3e9, 5e9),
        ("starcoder2-7b", 6.5e9, 8.5e9),
        ("xlstm-350m", 2.0e8, 5e8),   # simplified block internals
        ("recurrentgemma-2b", 2e9, 3.6e9),
    ])
    def test_nameplate(self, arch, lo, hi):
        n = param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n / 1e9:.2f}B params"


class TestOptimizedVariants:
    """§Perf hillclimb variants must preserve the math (sharding-only
    changes are exactly equal on one device; bf16 scores within tolerance)."""

    @pytest.mark.parametrize("arch", ["granite-8b", "deepseek-moe-16b",
                                      "xlstm-350m", "arctic-480b"])
    def test_optimized_config_equivalent(self, arch):
        base = get_config(arch, smoke=True)
        opt = get_config(arch, smoke=True, optimized=True)
        params = init_params(KEY, base)
        batch = make_batch(base, s=32)
        l1, _ = forward(params, batch, base)
        l2, _ = forward(params, batch, opt)
        scale = float(np.max(np.abs(np.asarray(l1, np.float32)))) or 1.0
        err = float(np.max(np.abs(np.asarray(l1, np.float32)
                                  - np.asarray(l2, np.float32))))
        tol = 5e-2 * scale if opt.scores_dtype == "bfloat16" else 1e-5
        assert err <= tol, f"{arch}: optimized diverges by {err:.3e}"
