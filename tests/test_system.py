"""Top-level sanity: public API imports and the registry is complete."""
from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable


def test_ten_archs_registered():
    assert len(ARCH_IDS) == 10


def test_every_cell_defined():
    """40 (arch x shape) cells: each is either applicable or a documented
    skip with a reason."""
    n_app, n_skip = 0, 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, reason = shape_applicable(cfg, shape)
            if ok:
                n_app += 1
            else:
                assert reason
                n_skip += 1
    assert n_app + n_skip == 40
    assert n_skip == 8  # long_500k for the 8 full-attention archs


def test_smoke_configs_are_small():
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=True)
        assert cfg.d_model <= 128 and cfg.n_layers <= 12
