"""Parsing-overhead model (paper §3.2.1) + profile preprocessing."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.overhead import (OverheadModel, RecordedOp, RecordedStep,
                                 preprocess_recorded_step)


class TestFit:
    def test_exact_recovery(self):
        alpha, beta = 2e-9, 5e-4
        sizes = [1e5 * 2 ** i for i in range(8)]
        ys = [alpha * s + beta for s in sizes]
        m = OverheadModel.fit(sizes, ys)
        assert m.alpha == pytest.approx(alpha, rel=1e-6)
        assert m.beta == pytest.approx(beta, rel=1e-6)
        assert m.r_squared(sizes, ys) == pytest.approx(1.0)

    @settings(max_examples=30, deadline=None)
    @given(st.floats(1e-10, 1e-8), st.floats(1e-5, 1e-2))
    def test_recovery_under_parameter_sweep(self, alpha, beta):
        sizes = np.linspace(1e5, 5e7, 12)
        ys = alpha * sizes + beta
        m = OverheadModel.fit(sizes, ys)
        assert m.alpha == pytest.approx(alpha, rel=1e-4)
        assert m.beta == pytest.approx(beta, rel=1e-3)

    def test_nonnegative_clamp(self):
        m = OverheadModel.fit([1.0, 2.0, 3.0], [3.0, 2.0, 1.0])
        assert m.alpha >= 0.0


class TestPreprocess:
    def _step(self):
        ops = [
            RecordedOp("down/a", "downlink", deps=(), size=1000,
                       start=0.0, end=2.0),
            RecordedOp("fwd/a", "worker", deps=(0,), start=2.0, end=3.0),
            RecordedOp("up/a", "uplink", deps=(1,), size=500,
                       start=3.0, end=4.0),
            RecordedOp("upd/a", "ps", deps=(2,), start=4.0, end=4.5),
        ]
        return RecordedStep(ops=ops)

    def test_comm_split_into_link_and_parse(self):
        m = OverheadModel(alpha=1e-3, beta=0.1)
        tpl = preprocess_recorded_step(self._step(), m)
        names = [op.name for op in tpl.ops]
        assert "down/a" in names and "down/a/parse" in names
        assert "up/a" in names and "up/a/parse" in names
        link = tpl.ops[names.index("down/a")]
        assert link.size == 1000 and link.duration == 0.0
        parse = tpl.ops[names.index("down/a/parse")]
        assert parse.duration == pytest.approx(1e-3 * 1000 + 0.1)
        assert parse.res == "parse"

    def test_dependents_repointed_at_parse_op(self):
        """fwd must wait for the downlink's PARSE, not just the transfer."""
        m = OverheadModel(alpha=0.0, beta=0.0)
        tpl = preprocess_recorded_step(self._step(), m)
        names = [op.name for op in tpl.ops]
        fwd = tpl.ops[names.index("fwd/a")]
        assert names.index("down/a/parse") in fwd.deps

    def test_uplink_parse_on_ps_resource(self):
        m = OverheadModel(alpha=0.0, beta=1.0)
        tpl = preprocess_recorded_step(self._step(), m)
        names = [op.name for op in tpl.ops]
        assert tpl.ops[names.index("up/a/parse")].res == "ps"

    def test_compute_durations_preserved(self):
        m = OverheadModel(alpha=0.0, beta=0.0)
        tpl = preprocess_recorded_step(self._step(), m)
        names = [op.name for op in tpl.ops]
        assert tpl.ops[names.index("fwd/a")].duration == pytest.approx(1.0)
        assert tpl.ops[names.index("upd/a")].duration == pytest.approx(0.5)
