"""Batched waterfill vs the scalar solver, over the shared STRUCTURES.

``batched_waterfill`` + ``stack_waterfill_problems`` must reproduce
:func:`repro.core.bandwidth.waterfill` per problem row — same max-min
allocations to float-accumulation tolerance — including heterogeneous
problem sizes padded into one stack, weighted flows, and every group
structure the incremental differential suite exercises.  The JAX backend
is a float32 scoring surrogate and gets a looser tolerance.
"""
import random

import numpy as np
import pytest

from repro.core.bandwidth import (batched_waterfill,
                                  stack_waterfill_problems, waterfill)
from test_waterfill_incremental import STRUCTURES

RTOL = 1e-9


def random_problems(structure, seed, n, weighted=False):
    """n random active-subset problems over one structure's universe."""
    model, universe = STRUCTURES[structure]()
    rng = random.Random(seed)
    problems = []
    for _ in range(n):
        k = rng.randrange(1, len(universe) + 1)
        conns = sorted(rng.sample(list(universe), k))
        caps, members = model.groups_for(conns)
        if weighted:
            w = {c: rng.uniform(0.2, 3.0) for c in conns}
            problems.append((conns, caps, members, w))
        else:
            problems.append((conns, caps, members))
    return problems


def assert_stack_matches_scalar(problems, backend="numpy", rtol=RTOL):
    cols, caps, members, weights = stack_waterfill_problems(problems)
    shares = batched_waterfill(caps, members, weights, backend=backend)
    for b, prob in enumerate(problems):
        conns = prob[0]
        w = prob[3] if len(prob) > 3 else None
        ref = waterfill(conns, prob[1], prob[2], weights=w)
        got = {c: shares[b, j] for j, c in enumerate(cols[b])}
        for c in conns:
            assert got[c] == pytest.approx(ref[c], rel=rtol, abs=1e-12), (
                f"problem {b} conn {c}: batched {got[c]} vs "
                f"scalar {ref[c]}")
        # phantom padding columns must stay at exactly zero
        for j in range(len(conns), shares.shape[1]):
            assert shares[b, j] == 0.0


@pytest.mark.parametrize("structure", sorted(STRUCTURES))
@pytest.mark.parametrize("seed", range(10))
def test_batched_matches_scalar(structure, seed):
    assert_stack_matches_scalar(random_problems(structure, seed, 8))


@pytest.mark.parametrize("structure", ["star", "racked_asym_nic",
                                       "loopback"])
@pytest.mark.parametrize("seed", range(5))
def test_batched_weighted(structure, seed):
    assert_stack_matches_scalar(
        random_problems(structure, 500 + seed, 6, weighted=True))


def test_heterogeneous_stack():
    """Problems of different sizes AND different group structures pad
    into one stack without cross-talk."""
    problems = []
    for i, structure in enumerate(sorted(STRUCTURES)):
        problems += random_problems(structure, 900 + i, 3)
    assert_stack_matches_scalar(problems)


def test_uncovered_connection_raises():
    model, universe = STRUCTURES["star"]()
    conns = sorted(universe)[:3]
    caps, members = model.groups_for(conns)
    bogus = conns + [("ghost", "nowhere")]
    with pytest.raises(ValueError, match="no capacity group"):
        stack_waterfill_problems([(bogus, caps, members)])


def test_empty_stack_raises():
    with pytest.raises(ValueError, match=">= 1 problem"):
        stack_waterfill_problems([])


def test_unknown_backend_raises():
    with pytest.raises(ValueError, match="unknown backend"):
        batched_waterfill(np.ones((1, 1)), np.ones((1, 1, 2), bool),
                          backend="cuda")


def test_jax_backend_close():
    pytest.importorskip("jax")
    problems = random_problems("star", 7, 6)
    problems += random_problems("grouped", 8, 6)
    assert_stack_matches_scalar(problems, backend="jax", rtol=2e-4)
