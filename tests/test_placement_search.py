"""Placement search engine: oracle agreement, determinism, validation.

Covers: greedy and annealing vs the exhaustive oracle on small
placement-sensitive topologies, serial == parallel determinism under a
fixed seed, baseline never-worse guarantees, evaluator memoization, and
input-validation errors for infeasible placements and oversized
exhaustive spaces.  All through cheap synthetic templates
(``evaluator_from_templates``) — the full-pipeline path
(``evaluator_from_run``) is exercised by ``benchmarks/fig_placement.py``
and the whatif CLI.
"""
import pytest

from repro.core.events import Op, StepTemplate
from repro.core.placement_search import (DEFAULT_MAX_EXHAUSTIVE,
                                         evaluator_from_templates,
                                         search_placement)
from repro.core.topology import Node, Rack, Topology

BW = 1e2


def comm_heavy_steps(n_layers=3, size=200.0, compute=0.02, num_ps=1):
    """Bandwidth-bound steps; layers round-robin over ``num_ps`` shards."""
    ops = []
    for i in range(n_layers):
        p = i % num_ps
        dn = "downlink" if num_ps == 1 else f"downlink:{p}"
        up = "uplink" if num_ps == 1 else f"uplink:{p}"
        dl = len(ops)
        ops.append(Op(f"d{i}", dn, size=size))
        ops.append(Op(f"f{i}", "worker", duration=compute, deps=(dl,)))
        ops.append(Op(f"u{i}", up, size=size, deps=(dl + 1,)))
    return [StepTemplate(ops=ops)]


def rack_pool_topology(num_shards=2, oversub=8.0):
    """Default placement behind an oversubscribed rack uplink; an equal
    number of free nodes sit in the flat rack — the obvious optimum."""
    bad = tuple(Node(f"bad{p}", rack="r0") for p in range(num_shards))
    good = tuple(Node(f"good{p}", rack="r1") for p in range(num_shards))
    return Topology(
        workers=tuple(Node(f"w{i}", rack="r1") for i in range(3)),
        ps_nodes=bad + good,
        racks=(Rack("r0", oversubscription=oversub), Rack("r1")),
        bandwidth=BW,
    ).with_placement(tuple(n.name for n in bad))


def make_evaluator(topo, num_ps=None, **kw):
    num_ps = topo.num_shards if num_ps is None else num_ps
    kw.setdefault("link_policy", "fifo")
    return evaluator_from_templates(
        topo, comm_heavy_steps(num_ps=num_ps), num_workers=3, n_runs=1,
        steps_per_worker=12, **kw)


class TestOracleAgreement:
    @pytest.mark.parametrize("num_shards", [1, 2, 3])
    def test_greedy_matches_exhaustive(self, num_shards):
        topo = rack_pool_topology(num_shards)
        ev = make_evaluator(topo)
        exact = search_placement(ev, "exhaustive")
        greedy = search_placement(ev, "greedy")
        assert greedy.throughput >= 0.99 * exact.throughput

    def test_greedy_matches_exhaustive_4_shards(self):
        """4 shards over 5 hosts (sharding + colocation in play): the
        largest cluster the ISSUE gates against the oracle."""
        topo = rack_pool_topology(4)
        ev = make_evaluator(topo)
        hosts = ("bad0", "bad1", "good0", "good1", "w0")
        exact = search_placement(ev, "exhaustive", hosts=hosts,
                                 max_exhaustive=700)
        greedy = search_placement(ev, "greedy", hosts=hosts)
        assert greedy.throughput >= 0.99 * exact.throughput

    def test_anneal_at_least_greedy(self):
        topo = rack_pool_topology(2)
        ev = make_evaluator(topo)
        greedy = search_placement(ev, "greedy")
        anneal = search_placement(ev, "anneal", seed=11)
        assert anneal.throughput >= greedy.throughput

    def test_finds_the_planted_optimum(self):
        """With an 8x-oversubscribed default rack the flat-rack nodes are
        the planted optimum; every strategy must escape the default."""
        topo = rack_pool_topology(2)
        ev = make_evaluator(topo)
        for strategy in ("exhaustive", "greedy", "anneal"):
            res = search_placement(ev, strategy)
            assert res.speedup > 1.5, (strategy, res)
            assert not any(h.startswith("bad") for h in res.placement)

    def test_uniform_cluster_keeps_default(self):
        """No structure -> nothing to gain; the default placement (or an
        equivalent) must be returned, never something worse."""
        topo = rack_pool_topology(2, oversub=1.0)
        ev = make_evaluator(topo)
        res = search_placement(ev, "greedy", colocation=False)
        assert res.throughput >= res.baseline_throughput


class TestDeterminism:
    @pytest.mark.parametrize("strategy", ["greedy", "anneal"])
    def test_serial_equals_parallel(self, monkeypatch, strategy):
        topo = rack_pool_topology(2)
        par = search_placement(make_evaluator(topo), strategy, seed=5)
        monkeypatch.setenv("REPRO_SWEEP_SERIAL", "1")
        ser = search_placement(make_evaluator(topo), strategy, seed=5)
        assert ser.placement == par.placement
        assert ser.throughput == par.throughput   # bit-identical

    def test_fixed_seed_reproducible(self):
        topo = rack_pool_topology(2)
        a = search_placement(make_evaluator(topo), "anneal", seed=7)
        b = search_placement(make_evaluator(topo), "anneal", seed=7)
        assert (a.placement, a.throughput) == (b.placement, b.throughput)


class TestEvaluator:
    def test_memoizes(self):
        ev = make_evaluator(rack_pool_topology(2))
        s1 = ev.score(("good0", "good1"))
        n = ev.evaluated
        s2 = ev.score(("good0", "good1"))
        assert s1 == s2 and ev.evaluated == n

    def test_strategies_share_the_cache(self):
        ev = make_evaluator(rack_pool_topology(2))
        search_placement(ev, "exhaustive")
        before = ev.evaluated
        res = search_placement(ev, "greedy")
        # greedy only revisits placements the oracle already scored
        assert ev.evaluated == before
        assert res.evaluated == 0

    def test_candidate_hosts_order(self):
        ev = make_evaluator(rack_pool_topology(1))
        assert ev.candidate_hosts(colocation=False) == (
            "bad0", "good0")
        assert ev.candidate_hosts() == ("bad0", "good0", "w0", "w1", "w2")


class TestValidation:
    def test_unknown_strategy(self):
        ev = make_evaluator(rack_pool_topology(1))
        with pytest.raises(ValueError, match="unknown strategy"):
            search_placement(ev, "ilp")

    def test_unknown_host(self):
        ev = make_evaluator(rack_pool_topology(1))
        with pytest.raises(ValueError, match="not a node of this topology"):
            search_placement(ev, "greedy", hosts=("good0", "nope"))

    def test_duplicate_host(self):
        ev = make_evaluator(rack_pool_topology(1))
        with pytest.raises(ValueError, match="duplicate candidate host"):
            search_placement(ev, "greedy", hosts=("good0", "good0"))

    def test_empty_hosts(self):
        ev = make_evaluator(rack_pool_topology(1))
        with pytest.raises(ValueError, match="at least one candidate"):
            search_placement(ev, "greedy", hosts=())

    def test_wrong_placement_length(self):
        ev = make_evaluator(rack_pool_topology(2))
        with pytest.raises(ValueError, match="2 PS shard"):
            ev.score(("good0",))

    def test_bad_start_placement(self):
        ev = make_evaluator(rack_pool_topology(2))
        with pytest.raises(ValueError, match="not a node of this topology"):
            search_placement(ev, "greedy", start=("good0", "zzz"))

    def test_exhaustive_space_capped(self):
        ev = make_evaluator(rack_pool_topology(2))
        with pytest.raises(ValueError, match="use strategy='greedy'"):
            search_placement(ev, "exhaustive", max_exhaustive=3)
        assert DEFAULT_MAX_EXHAUSTIVE >= 4096


class TestIncrementalWaterfill:
    """PR-5 gates: the incremental group-local allocator must leave the
    search results untouched (same placements, same scores as the
    pre-incremental batch path) and candidate evaluation must actually
    issue group-local re-solves, not hidden full re-waterfills."""

    @pytest.mark.parametrize("strategy", ["greedy", "anneal"])
    @pytest.mark.parametrize("num_shards", [2, 3])
    def test_search_identical_to_batch_path(self, strategy, num_shards):
        """The fig_placement families' regime (oversubscribed default rack
        vs flat spare rack): batch and incremental engines must pick the
        same placements with scores equal to float noise."""
        topo = rack_pool_topology(num_shards)
        res_i = search_placement(make_evaluator(topo, waterfill="auto"),
                                 strategy, seed=5)
        res_b = search_placement(make_evaluator(topo, waterfill="batch"),
                                 strategy, seed=5)
        assert res_i.placement == res_b.placement
        assert res_i.baseline_placement == res_b.baseline_placement
        assert res_i.throughput == pytest.approx(res_b.throughput,
                                                 rel=1e-9)
        assert res_i.baseline_throughput == pytest.approx(
            res_b.baseline_throughput, rel=1e-9)
        assert res_i.evaluated == res_b.evaluated

    def test_candidate_evaluation_is_group_local(self):
        """One candidate simulation, instrumented: most flushes are served
        from the recurring-membership memo, true component solves are
        rare, and the re-solved footprint stays below the full active set
        — i.e. candidate evaluation issues group-local re-solves only."""
        from repro.core.simulator import SimConfig, Simulation
        topo = rack_pool_topology(2)
        cfg = SimConfig(topology=topo, steps_per_worker=12, warmup_steps=2,
                        seed=0, link_policy="fifo")
        trace = Simulation(cfg).run(comm_heavy_steps(num_ps=2), 3)
        stats = trace.meta["waterfill"]
        assert stats["flushes"] > 20
        # memoized group-local lookups dominate; full solves of the
        # constraint graph are the exception, not the rule
        assert stats["comp_solves"] < 0.25 * stats["flushes"]
        assert stats["memo_hits"] > stats["comp_solves"]
        assert stats["resolved_conns"] < 0.85 * stats["active_conn_events"]

    def test_batch_mode_has_no_solver_stats(self):
        from repro.core.simulator import SimConfig, Simulation
        topo = rack_pool_topology(2)
        cfg = SimConfig(topology=topo, steps_per_worker=6, warmup_steps=2,
                        seed=0, link_policy="fifo", waterfill="batch")
        trace = Simulation(cfg).run(comm_heavy_steps(num_ps=2), 3)
        assert "waterfill" not in trace.meta


class TestSurrogate:
    """The batched-waterfill prefilter: DES spend drops by an order of
    magnitude while the chosen placement matches the exhaustive oracle
    (or ties it exactly — symmetric placements simulate identically)."""

    @staticmethod
    def bypass_topology(num_shards=2, oversub=8.0):
        """rack_pool_topology with ``loopback_bypass``: colocated conns
        skip the NIC/rack groups, giving the steady-state proxy the
        capacity signal that makes colocation rankable (without it the
        conservative model scores w0 placements on scheduling noise the
        proxy cannot see)."""
        bad = tuple(Node(f"bad{p}", rack="r0") for p in range(num_shards))
        good = tuple(Node(f"good{p}", rack="r1") for p in range(num_shards))
        return Topology(
            workers=tuple(Node(f"w{i}", rack="r1") for i in range(3)),
            ps_nodes=bad + good,
            racks=(Rack("r0", oversubscription=oversub), Rack("r1")),
            bandwidth=BW, loopback_bypass=True,
        ).with_placement(tuple(n.name for n in bad))

    @pytest.mark.parametrize("num_shards", [1, 2])
    def test_matches_exhaustive(self, num_shards):
        topo = rack_pool_topology(num_shards)
        hosts = tuple(n.name for n in topo.ps_nodes)
        # fresh evaluators per strategy: the shared memoized cache would
        # otherwise hide how much DES work the surrogate really spends
        exact = search_placement(make_evaluator(topo), "exhaustive",
                                 hosts=hosts)
        sur = search_placement(make_evaluator(topo), "surrogate",
                               hosts=hosts)
        assert (sur.placement == exact.placement
                or sur.throughput == exact.throughput)
        assert sur.throughput >= sur.baseline_throughput * (1 - 1e-9)

    def test_matches_exhaustive_with_colocation(self):
        """Full host list including the workers: on the bypass topology
        the surrogate must find the same colocated optimum as the
        oracle's 49-candidate enumeration."""
        topo = self.bypass_topology(2)
        exact = search_placement(make_evaluator(topo), "exhaustive")
        sur = search_placement(make_evaluator(topo), "surrogate")
        assert (sur.placement == exact.placement
                or sur.throughput == exact.throughput)
        assert any(h.startswith("w") for h in sur.placement)

    def test_prunes_the_space(self):
        """2 shards over 7 hosts = 49 candidates: the shortlist plus the
        baseline must stay >= 5x below the enumerated space."""
        topo = rack_pool_topology(2)
        ev = make_evaluator(topo)
        res = search_placement(ev, "surrogate")
        space = len(ev.candidate_hosts()) ** 2
        assert res.evaluated * 5 <= space, (res.evaluated, space)

    def test_surrogate_space_capped(self):
        ev = make_evaluator(rack_pool_topology(2))
        with pytest.raises(ValueError, match="use strategy='greedy'"):
            search_placement(ev, "surrogate", surrogate_cap=3)

    def test_surrogate_scores_rank_the_planted_optimum(self):
        """The proxy alone (no DES at all) must rank the flat-rack nodes
        above the oversubscribed default ones."""
        from repro.core.placement_search import surrogate_scores
        ev = make_evaluator(rack_pool_topology(1))
        scores = surrogate_scores(ev, [("bad0",), ("good0",)])
        assert scores[1] > scores[0]


class TestStragglerWhatIf:
    """The ROADMAP straggler knob: Node.speed threads through prediction
    AND the topology-aware emulator, and both report consistent
    degradation (same measurement convention, same cluster)."""

    def test_with_node_speed_validation(self):
        t = Topology.star(2, 1)
        with pytest.raises(ValueError, match="speed must be > 0"):
            t.with_node_speed("w0", 0.0)
        with pytest.raises(KeyError):
            t.with_node_speed("nope", 0.5)

    def test_with_node_speed_patches_one_node(self):
        t = Topology.star(2, 1).with_node_speed("w0", 0.5)
        assert t.node("w0").speed == 0.5
        assert t.node("w1").speed == 1.0
        assert t.node("ps0").speed == 1.0
        assert t.worker_speeds() == {0: 0.5}

    def test_predicted_degradation_matches_emulator(self):
        """Predict the straggler ratio and validate it against the
        topology-aware emulator (the satellite's acceptance check)."""
        from repro.core.predictor import PredictionRun
        base = PredictionRun(dnn="googlenet", batch_size=16,
                             platform="private_cpu", profile_steps=15,
                             sim_steps=80).prepare()
        star = Topology.star(2, 1)
        strag = star.with_node_speed("w0", 1.0 / 2.0)
        pred_ratio = (base.with_topology(strag).predict(2, n_runs=2)
                      / base.with_topology(star).predict(2, n_runs=2))
        meas_ratio = (base.with_topology(strag).measure(2, steps=40)
                      / base.with_topology(star).measure(2, steps=40))
        assert pred_ratio < 0.8          # the slowdown is clearly visible
        assert meas_ratio < 0.8
        assert pred_ratio == pytest.approx(meas_ratio, abs=0.15)
