"""Emulator fabric-pool parity: incremental vs batch, plus a golden trace.

The topology-mode fabric pool now solves its weighted max-min shares with
``IncrementalWaterfill`` (group-local re-solves).  Because the incremental
solver is bit-identical to the batch solver and both fabric modes share
every other line of the event machinery, a fixed workload must produce
**byte-for-byte identical** rate trajectories, step completions and
throughput under ``fabric_mode="incremental"`` and ``fabric_mode="batch"``
(the pre-incremental pool behavior, kept as the live oracle the way
``simulator_ref.py`` gates the DES engine).

A small frozen fixture (``tests/data/fabric_pool_golden.json``) addition-
ally pins the batch pool's rate trajectory itself, so solver-level drift
that changes both modes in lockstep is still caught.  Regenerate it after
a *deliberate* semantic change with:

    REPRO_REGEN_FIXTURES=1 python -m pytest tests/test_fabric_parity.py
"""
import json
import os

import pytest

from repro.core.paper_models import PAPER_DNNS, PLATFORMS
from repro.core.topology import Topology
from repro.emulator.cluster import ClusterEmulator

FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                       "fabric_pool_golden.json")

# the fixed workload: racked topology so rack-uplink groups couple links,
# background flows on (aws_cpu's bg_rate), bandwidth-weight jitter on
WORKLOAD = dict(dnn="googlenet", batch=32, platform="aws_cpu",
                num_workers=6, seed=3, steps=12)


def _topology():
    return Topology.racked(6, 2, racks=2, oversubscription=3.0)


def _norm_conn(conn):
    """Background flows ride unique pseudo-worker connections whose ids
    come from a process-global counter; normalize them so two emulator
    instances (or a frozen fixture) compare equal."""
    w, lid = conn
    return ["bg", lid] if w < 0 else [w, lid]


def _run(fabric_mode, rate_log_limit=None):
    emu = ClusterEmulator(PAPER_DNNS[WORKLOAD["dnn"]], WORKLOAD["batch"],
                          PLATFORMS[WORKLOAD["platform"]],
                          num_workers=WORKLOAD["num_workers"],
                          seed=WORKLOAD["seed"], topology=_topology(),
                          fabric_mode=fabric_mode)
    emu.fabric.rate_log = []
    emu.run(steps_per_worker=WORKLOAD["steps"])
    log = [[t, _norm_conn(c), r] for t, c, r in emu.fabric.rate_log]
    if rate_log_limit is not None:
        log = log[:rate_log_limit]
    return emu, log


def test_incremental_pool_matches_batch_pool_bit_for_bit():
    emu_b, log_b = _run("batch")
    emu_i, log_i = _run("incremental")
    # the full rate trajectory — every (time, connection, rate) assignment
    # the pool ever made — must be byte-for-byte identical
    assert log_i == log_b
    assert emu_i.step_completion_times == emu_b.step_completion_times
    assert emu_i.throughput(warmup_steps=4) == emu_b.throughput(
        warmup_steps=4)
    # and the incremental pool must actually have solved incrementally
    assert emu_i.fabric.iwf is not None
    assert emu_i.fabric.iwf.stats["flushes"] > 0
    assert emu_b.fabric.iwf is None


def test_batch_pool_matches_golden_fixture():
    """Solver-level golden gate: the batch pool's trajectory pinned at PR-5
    time.  Tolerant to last-ulp libm differences across runners (rel 1e-12)
    but exact on structure, ordering and step completions."""
    emu, log = _run("batch", rate_log_limit=400)
    payload = {
        "workload": WORKLOAD,
        "rate_log": log,
        "step_completions": [[w, s, t]
                             for w, s, t in emu.step_completion_times],
        "throughput": emu.throughput(warmup_steps=4),
    }
    if os.environ.get("REPRO_REGEN_FIXTURES"):
        os.makedirs(os.path.dirname(FIXTURE), exist_ok=True)
        with open(FIXTURE, "w") as f:
            json.dump(payload, f, indent=1)
        pytest.skip(f"regenerated {FIXTURE}")
    with open(FIXTURE) as f:
        golden = json.load(f)
    assert golden["workload"] == payload["workload"]
    assert len(payload["rate_log"]) == len(golden["rate_log"])
    for got, want in zip(payload["rate_log"], golden["rate_log"]):
        assert got[1] == want[1]
        assert got[0] == pytest.approx(want[0], rel=1e-12, abs=1e-15)
        assert got[2] == pytest.approx(want[2], rel=1e-12)
    assert [x[:2] for x in payload["step_completions"]] == \
           [x[:2] for x in golden["step_completions"]]
    for got, want in zip(payload["step_completions"],
                         golden["step_completions"]):
        assert got[2] == pytest.approx(want[2], rel=1e-12)
    assert payload["throughput"] == pytest.approx(golden["throughput"],
                                                  rel=1e-12)


def test_fabric_mode_validated():
    with pytest.raises(ValueError, match="fabric_mode"):
        ClusterEmulator(PAPER_DNNS["googlenet"], 32, PLATFORMS["aws_cpu"],
                        num_workers=2, topology=_topology(),
                        fabric_mode="bogus")
