"""Algorithm 3.1 simulator: exactness on crafted DAGs + invariants."""

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.events import Op, StepTemplate, ps_resources
from repro.core.simulator import SimConfig, Simulation

BW = 100.0  # bytes/s for easy arithmetic


def run_once(ops, workers=1, steps=1, policy="fifo", win=1e18, seed=0,
             bandwidth=BW):
    cfg = SimConfig(resources=ps_resources(bandwidth), link_policy=policy,
                    win=win, steps_per_worker=steps, warmup_steps=0,
                    seed=seed, record_op_times=True)
    sim = Simulation(cfg)
    tpl = StepTemplate(ops=list(ops))
    trace = sim.run([tpl], workers, sample=False)
    return trace


class TestSerialChain:
    def test_down_compute_up(self):
        """down(200B) -> fwd(1s) -> up(100B): serial = 2 + 1 + 1 = 4s."""
        ops = [Op("d", "downlink", size=200),
               Op("f", "worker", duration=1.0, deps=(0,)),
               Op("u", "uplink", size=100, deps=(1,))]
        tr = run_once(ops)
        assert tr.step_completions[0][2] == pytest.approx(4.0)

    def test_parallel_links_overlap(self):
        """Independent down(200B) and up(200B) overlap fully: 2s not 4s."""
        ops = [Op("d", "downlink", size=200), Op("u", "uplink", size=200)]
        tr = run_once(ops)
        assert tr.step_completions[0][2] == pytest.approx(2.0)

    def test_compute_overlaps_comm(self):
        """fwd ready at t=0 runs while a big downlink streams."""
        ops = [Op("d", "downlink", size=1000),
               Op("f", "worker", duration=5.0)]
        tr = run_once(ops)
        assert tr.step_completions[0][2] == pytest.approx(10.0)


class TestBandwidthSharing:
    def test_two_workers_halve_rate(self):
        """Two workers with one 100B downlink each on a 100B/s link:
        processor sharing finishes both at t=2 (not 1 and 2)."""
        ops = [Op("d", "downlink", size=100)]
        tr = run_once(ops, workers=2)
        times = sorted(t for _w, _s, t in tr.step_completions)
        assert times[0] == pytest.approx(2.0)
        assert times[1] == pytest.approx(2.0)

    def test_staggered_sharing(self):
        """w0: 100B at t=0; w1 joins after its 1s compute: w0 sees full
        rate for 1s (100B sent? no -> shares). Validate total time."""
        ops0 = [Op("d", "downlink", size=200)]
        # craft via two different steps is not supported in one call;
        # instead check conservation: total bytes / capacity <= makespan
        tr = run_once(ops0, workers=3)
        end = max(t for _w, _s, t in tr.step_completions)
        assert end == pytest.approx(3 * 200 / BW)  # saturated link


class TestHttp2Timing:
    def test_win_chunk_interleave(self):
        """A(150) then B(60) with WIN=100: A sends 100, B sends 60,
        A remainder 50. End(A)=2.1s, End(B)=1.6s."""
        ops = [Op("a", "downlink", size=150), Op("b", "downlink", size=60)]
        tr = run_once(ops, policy="http2", win=100)
        times = {name: e for _w, _s, name, _r, s, e in tr.op_times}
        assert times["b"] == pytest.approx(1.6)
        assert times["a"] == pytest.approx(2.1)


class TestDependencies:
    def test_diamond(self):
        ops = [Op("a", "worker", duration=1.0),
               Op("b", "downlink", size=100, deps=(0,)),
               Op("c", "uplink", size=100, deps=(0,)),
               Op("d", "worker", duration=1.0, deps=(1, 2))]
        tr = run_once(ops)
        assert tr.step_completions[0][2] == pytest.approx(3.0)

    def test_cycle_rejected(self):
        with pytest.raises(ValueError):
            StepTemplate(ops=[Op("a", "worker", duration=1, deps=(1,)),
                              Op("b", "worker", duration=1, deps=(0,))])

    def test_multi_step_steady_state(self):
        ops = [Op("d", "downlink", size=100),
               Op("f", "worker", duration=1.0, deps=(0,)),
               Op("u", "uplink", size=100, deps=(1,))]
        tr = run_once(ops, steps=5)
        ends = [t for _w, _s, t in tr.step_completions]
        diffs = [b - a for a, b in zip(ends, ends[1:])]
        for d in diffs:
            assert d == pytest.approx(3.0)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["downlink", "worker", "uplink"]),
                          st.floats(1.0, 50.0)),
                min_size=1, max_size=8),
       st.integers(1, 3))
def test_property_makespan_bounds(chain, workers):
    """For a serial chain, makespan must lie between the critical-path
    lower bound and the fully-serialized upper bound, and total completed
    steps must equal workers * steps."""
    ops = []
    for i, (res, amount) in enumerate(chain):
        deps = (i - 1,) if i else ()
        if res == "worker":
            ops.append(Op(f"o{i}", res, duration=amount, deps=deps))
        else:
            ops.append(Op(f"o{i}", res, size=amount, deps=deps))
    tr = run_once(ops, workers=workers)
    assert len(tr.step_completions) == workers
    serial = sum(a if r == "worker" else a / BW for r, a in chain)
    end = max(t for _w, _s, t in tr.step_completions)
    # lower bound: serial chain of one worker; upper: all work serialized
    assert end >= serial - 1e-6
    assert end <= workers * serial + 1e-6
