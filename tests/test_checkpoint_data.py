"""Checkpoint manager (atomicity, restart equivalence, elastic re-shard)
and the synthetic data pipeline."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ck
from repro.configs import get_config
from repro.data import SyntheticLM


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(12.0).reshape(3, 4),
                "b": {"c": jnp.ones((5,), jnp.int32)}}
        ck.save(str(tmp_path), 7, tree, metadata={"k": "v"})
        out, meta = ck.restore(str(tmp_path), tree)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))
        assert meta == {"k": "v"}
        assert ck.latest_step(str(tmp_path)) == 7

    def test_latest_pointer_advances(self, tmp_path):
        tree = {"a": jnp.zeros(2)}
        ck.save(str(tmp_path), 1, tree)
        ck.save(str(tmp_path), 5, tree)
        assert ck.latest_step(str(tmp_path)) == 5

    def test_structure_mismatch_rejected(self, tmp_path):
        ck.save(str(tmp_path), 0, {"a": jnp.zeros(2)})
        with pytest.raises(ValueError):
            ck.restore(str(tmp_path), {"a": jnp.zeros(2),
                                       "b": jnp.zeros(3)})

    def test_shape_mismatch_rejected(self, tmp_path):
        ck.save(str(tmp_path), 0, {"a": jnp.zeros(2)})
        with pytest.raises(ValueError):
            ck.restore(str(tmp_path), {"a": jnp.zeros(3)})

    def test_cleanup_keeps_newest(self, tmp_path):
        tree = {"a": jnp.zeros(1)}
        for s in range(6):
            ck.save(str(tmp_path), s, tree)
        ck.cleanup(str(tmp_path), keep=2)
        dirs = sorted(d for d in os.listdir(tmp_path)
                      if d.startswith("step_"))
        assert dirs == ["step_00000004", "step_00000005"]

    def test_overwrite_crash_window_preserves_old_checkpoint(
            self, tmp_path, monkeypatch):
        """Regression: ``save()`` used to rmtree the old step dir before
        renaming the new one in — a crash in that window left the step
        with NO valid checkpoint.  The swap path must keep the old data
        restorable when the final rename fails, and heal the moved-aside
        copy on the next save."""
        old = {"a": jnp.arange(4.0)}
        new = {"a": jnp.arange(4.0) * 10.0}
        ck.save(str(tmp_path), 3, old)

        step_dir = os.path.join(str(tmp_path), "step_00000003")
        real_rename = os.rename

        def failing_rename(src, dst):
            if dst == step_dir and os.path.basename(src).startswith(".tmp_"):
                raise OSError("simulated crash mid-swap")
            return real_rename(src, dst)

        monkeypatch.setattr(os, "rename", failing_rename)
        with pytest.raises(OSError, match="mid-swap"):
            ck.save(str(tmp_path), 3, new)
        monkeypatch.undo()

        # the old checkpoint survived the crash window
        out, _ = ck.restore(str(tmp_path), old, step=3)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(old["a"]))
        # no trash/tmp leakage into the step listing, and a clean
        # overwrite still works afterwards
        ck.cleanup(str(tmp_path), keep=5)
        out, _ = ck.restore(str(tmp_path), old, step=3)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(old["a"]))
        ck.save(str(tmp_path), 3, new)
        out, _ = ck.restore(str(tmp_path), new, step=3)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(new["a"]))

    def test_interrupted_swap_healed_on_next_save(self, tmp_path):
        """A crash AFTER the old dir moved aside but BEFORE the new rename
        leaves only the dot-prefixed trash copy; the next save must put it
        back before swapping (so a concurrent restore never 404s)."""
        old = {"a": jnp.arange(3.0)}
        ck.save(str(tmp_path), 1, old)
        step_dir = os.path.join(str(tmp_path), "step_00000001")
        trash = os.path.join(str(tmp_path), ".old_step_00000001")
        os.rename(step_dir, trash)   # simulate the crash state
        new = {"a": jnp.arange(3.0) + 5.0}
        ck.save(str(tmp_path), 1, new)
        assert not os.path.exists(trash)
        out, _ = ck.restore(str(tmp_path), new, step=1)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(new["a"]))

    def test_restart_equivalence(self, tmp_path):
        """Train N steps straight == train, crash, resume (same losses)."""
        from repro.launch.train import build_argparser, run
        ap = build_argparser()
        base = ["--arch", "xlstm-350m", "--steps", "12", "--batch", "2",
                "--seq", "16", "--ckpt-every", "4", "--log-every", "100"]
        r1 = run(ap.parse_args(base + ["--ckpt-dir",
                                       str(tmp_path / "a")]))
        # crash at step 9, then resume
        with pytest.raises(RuntimeError):
            run(ap.parse_args(base + ["--ckpt-dir", str(tmp_path / "b"),
                                      "--fail-at", "9"]))
        r2 = run(ap.parse_args(base + ["--ckpt-dir", str(tmp_path / "b")]))
        assert r2["last_loss"] == pytest.approx(r1["last_loss"], rel=1e-4)

    def test_elastic_reshard_on_restore(self, tmp_path):
        """Save unsharded, restore onto a (4,2)-device mesh: values equal,
        shardings follow the restore-time mesh rules (subprocess: needs 8
        host devices)."""
        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
import sys
sys.path.insert(0, "src")
from repro import checkpoint as ck
tree = {"mlp": {"wi": jnp.arange(32.0).reshape(4, 8)}}
ck.save(sys.argv[1], 0, tree)
mesh = jax.make_mesh((4, 2), ("data", "model"))
out, _ = ck.restore(sys.argv[1], tree, mesh=mesh)
np.testing.assert_array_equal(np.asarray(out["mlp"]["wi"]),
                              np.asarray(tree["mlp"]["wi"]))
sh = out["mlp"]["wi"].sharding
assert not sh.is_fully_replicated, sh
mesh2 = jax.make_mesh((8, 1), ("data", "model"))
out2, _ = ck.restore(sys.argv[1], tree, mesh=mesh2)
np.testing.assert_array_equal(np.asarray(out2["mlp"]["wi"]),
                              np.asarray(tree["mlp"]["wi"]))
print("OK")
"""
        r = subprocess.run([sys.executable, "-c", code, str(tmp_path)],
                           capture_output=True, text=True,
                           cwd="/root/repo", timeout=300)
        assert "OK" in r.stdout, r.stderr[-2000:]


class TestDataPipeline:
    def test_deterministic(self):
        cfg = get_config("gemma-7b", smoke=True)
        a = SyntheticLM(cfg, 8, 32, seed=3)
        b = SyntheticLM(cfg, 8, 32, seed=3)
        for _ in range(3):
            ba, bb = a.next_batch(), b.next_batch()
            np.testing.assert_array_equal(np.asarray(ba["tokens"]),
                                          np.asarray(bb["tokens"]))

    def test_shards_differ_but_cover(self):
        cfg = get_config("gemma-7b", smoke=True)
        s0 = SyntheticLM(cfg, 8, 32, seed=3, shard=0, num_shards=2)
        s1 = SyntheticLM(cfg, 8, 32, seed=3, shard=1, num_shards=2)
        b0, b1 = s0.next_batch(), s1.next_batch()
        assert b0["tokens"].shape == (4, 32)
        assert not np.array_equal(np.asarray(b0["tokens"]),
                                  np.asarray(b1["tokens"]))

    def test_state_resume_bit_exact(self):
        cfg = get_config("gemma-7b", smoke=True)
        a = SyntheticLM(cfg, 4, 16, seed=1)
        a.next_batch()
        saved = a.state_dict()
        want = a.next_batch()
        b = SyntheticLM(cfg, 4, 16, seed=99)
        b.load_state_dict(saved)
        got = b.next_batch()
        np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                      np.asarray(want["tokens"]))

    def test_labels_shifted(self):
        cfg = get_config("gemma-7b", smoke=True)
        d = SyntheticLM(cfg, 2, 16, seed=0)
        b = d.next_batch()
        assert b["tokens"].shape == b["labels"].shape
        assert (np.asarray(b["labels"]) < cfg.vocab).all()

    def test_modality_stubs(self):
        cfg = get_config("whisper-small", smoke=True)
        d = SyntheticLM(cfg, 2, 16, seed=0)
        b = d.next_batch()
        assert b["frames"].shape == (2, cfg.encoder_len, cfg.d_model)
        cfg2 = get_config("llama-3.2-vision-90b", smoke=True)
        d2 = SyntheticLM(cfg2, 2, 16, seed=0)
        b2 = d2.next_batch()
        assert b2["enc_embed"].shape == (2, cfg2.cross_len, cfg2.d_model)
