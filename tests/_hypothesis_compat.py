"""Optional-import shim for ``hypothesis``.

The property-based tests are a bonus tier: when ``hypothesis`` is installed
(see requirements-dev.txt) they run as normal; when it is absent the
``@given(...)``-decorated tests are collected but skipped, and the rest of
the module's tests still run.  Import from here instead of from
``hypothesis`` directly:

    from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
"""
from __future__ import annotations

import pytest

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def decorate(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -r "
                       "requirements-dev.txt)")(fn)
        return decorate

    def settings(*_args, **_kwargs):
        def decorate(fn):
            return fn
        return decorate

    class _NullStrategies:
        """Stand-in for ``hypothesis.strategies``: module-level strategy
        construction (inside ``@given(...)`` arguments) must not crash at
        import time; the decorated tests are skipped anyway."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _NullStrategies()
