"""Differential harness: incremental vs batch water-filling.

The incremental allocator (``bandwidth.IncrementalWaterfill``) must stay
**bit-identical** — float for float, at every step — to the batch solver
(``bandwidth.waterfill``) it caches.  This module drives randomized
arrival/departure sequences through both and asserts share-for-share
equality after every flush, across the group structures the engines
actually compile: the paper's two-level star, heterogeneous link/NIC caps,
extra (rack-like) groups, topology-compiled groups with asymmetric
``nic_tx``/``nic_rx`` ports, loopback-bypass groups for colocated shards,
and weighted flows (the emulator's fabric pool).  This is the safety gate
that makes allocator rewrites cheap forever: any divergence — a stale
share, a mis-maintained component, a wrong cap — fails here first.

Set ``REPRO_CHECK_WATERFILL=1`` (as the CI ``waterfill-diff`` job does) to
additionally self-validate every flush inside the solver itself.
"""
import random

import pytest
from _hypothesis_compat import HAVE_HYPOTHESIS

from repro.core.bandwidth import (BandwidthModel, GroupedBandwidthModel,
                                  IncrementalWaterfill, waterfill)
from repro.core.topology import Node, Placement, Rack, Topology

# ---------------------------------------------------------------------------
# model structures under test
# ---------------------------------------------------------------------------


def _star_model():
    """The paper's two-level 2-PS star (homogeneous caps)."""
    model = BandwidthModel()
    links = [f"{d}:{p}" for d in ("downlink", "uplink") for p in range(2)]
    conns = [(w, r) for w in range(6) for r in links]
    return model, conns


def _grouped_model():
    """Heterogeneous caps + nested extra groups (rack-like)."""
    model = GroupedBandwidthModel(
        link_caps={"downlink:0": 2.0, "uplink:1": 0.5},
        worker_caps={0: 0.5, 3: 2.0},
        extra_groups=[
            ("fabric", 1.5, frozenset({"downlink:0", "downlink:1"})),
            ("pair", 0.8, frozenset({(1, "uplink:0"), (2, "uplink:0")})),
        ])
    links = [f"{d}:{p}" for d in ("downlink", "uplink") for p in range(2)]
    conns = [(w, r) for w in range(5) for r in links]
    return model, conns


def _racked_model():
    """Racked topology: rack uplink groups + asymmetric NIC caps."""
    topo = Topology(
        workers=tuple(
            Node(f"w{i}", nic_tx=0.5 if i % 2 else None,
                 nic_rx=2.0 if i == 0 else None, rack=f"r{i % 2}")
            for i in range(6)),
        ps_nodes=(Node("ps0", rack="r0"), Node("ps1", nic=2.0, rack="r1")),
        racks=(Rack("r0", oversubscription=3.0),
               Rack("r1", uplink_capacity=1.25)),
    )
    model = topo.grouped_model()
    links = [f"{d}:{p}" for d in ("downlink", "uplink") for p in range(2)]
    conns = [(w, r) for w in range(6) for r in links]
    return model, conns


def _loopback_model():
    """Colocated + sharded PS shards behind the loopback bypass."""
    topo = Topology(
        workers=tuple(Node(f"w{i}") for i in range(4)),
        ps_nodes=(Node("ps0"),),
        # shard 0 dedicated, shards 1+2 colocated on worker node w0
        placement=Placement(("ps0", "w0", "w0")),
        loopback_bypass=True, loopback_capacity=4.0,
    )
    model = topo.grouped_model()
    links = [f"{d}:{p}" for d in ("downlink", "uplink") for p in range(3)]
    conns = [(w, r) for w in range(4) for r in links]
    return model, conns


STRUCTURES = {
    "star": _star_model,
    "grouped": _grouped_model,
    "racked_asym_nic": _racked_model,
    "loopback": _loopback_model,
}


# ---------------------------------------------------------------------------
# the differential driver
# ---------------------------------------------------------------------------


def _batch_solve(model, active, weights=None):
    conns = sorted(active)
    caps, members = model.groups_for(conns)
    return waterfill(conns, caps, members, weights=weights)


def drive(model, universe, seed, *, weighted=False, events=50,
          batch_prob=0.35, check=False):
    """One seeded arrival/departure sequence through both solvers.

    Random joins/leaves (sometimes several per flush, like the DES batch
    windows), exact share comparison after every flush and once more at
    the end.  With ``weighted``, every connection carries a random weight
    (the emulator's per-flow bandwidth jitter)."""
    rng = random.Random(seed)
    iwf = IncrementalWaterfill(model.conn_groups, weighted=weighted,
                               check=check)
    active = {}
    for _ in range(events):
        if active and rng.random() < 0.45:
            c = rng.choice(sorted(active))
            del active[c]
            iwf.remove(c)
        else:
            c = universe[rng.randrange(len(universe))]
            if c in active:
                continue
            w = rng.uniform(0.2, 3.0) if weighted else 1.0
            active[c] = w
            iwf.add(c, weight=w)
        if rng.random() < batch_prob:
            continue          # batch several membership ops into one flush
        iwf.flush()
        expect = _batch_solve(model, active,
                              dict(active) if weighted else None)
        assert iwf.shares == expect, (
            f"divergence after {len(active)} active conns (seed {seed})")
    iwf.flush()
    expect = _batch_solve(model, active, dict(active) if weighted else None)
    assert iwf.shares == expect
    return iwf


# 60 seeds x 4 structures = 240 unweighted sequences (+ weighted below):
# well past the 200-sequence acceptance floor.
@pytest.mark.parametrize("structure", sorted(STRUCTURES))
@pytest.mark.parametrize("seed", range(60))
def test_differential_unweighted(structure, seed):
    model, universe = STRUCTURES[structure]()
    drive(model, universe, seed)


@pytest.mark.parametrize("structure", ["star", "racked_asym_nic",
                                       "loopback"])
@pytest.mark.parametrize("seed", range(25))
def test_differential_weighted(structure, seed):
    """Weighted max-min (the emulator fabric's regime), including unique
    pseudo-worker connections like its background flows."""
    model, universe = STRUCTURES[structure]()
    universe = list(universe) + [(-1 - i, universe[0][1]) for i in range(3)]
    drive(model, universe, 1000 + seed, weighted=True)


def test_share_values_change_only_when_reported():
    """flush() returns exactly the conns whose cached float moved — the
    contract the DES engine relies on to skip re-projections."""
    model, universe = STRUCTURES["star"]()
    rng = random.Random(7)
    iwf = IncrementalWaterfill(model.conn_groups)
    active = set()
    for _ in range(80):
        before = dict(iwf.shares)
        if active and rng.random() < 0.45:
            c = rng.choice(sorted(active))
            active.discard(c)
            iwf.remove(c)
        else:
            c = universe[rng.randrange(len(universe))]
            if c in active:
                continue
            active.add(c)
            iwf.add(c)
        changed = iwf.flush()
        for conn, share in iwf.shares.items():
            if conn in before and conn not in changed:
                assert share == before[conn], \
                    f"{conn} moved {before[conn]} -> {share} unreported"


def test_invariant_mode_catches_corruption():
    """REPRO_CHECK_WATERFILL semantics: a poisoned cache entry (the stale-
    share bug class this PR hardens against) must raise on the next
    flush, not silently propagate wrong rates."""
    model, universe = STRUCTURES["star"]()
    iwf = IncrementalWaterfill(model.conn_groups, check=True)
    for c in universe[:6]:
        iwf.add(c)
    iwf.flush()
    # poison a conn in a DIFFERENT component than the upcoming arrival:
    # the incremental flush must then leave the bad share in place for
    # the invariant check to catch (a victim inside the re-solved
    # component would be silently healed by the solve itself)
    victim = universe[0]
    assert not set(model.conn_groups(victim)) \
        & set(model.conn_groups(universe[6]))
    iwf.shares[victim] *= 0.5          # simulate a stale/corrupt share
    iwf.add(universe[6])
    with pytest.raises(AssertionError, match="diverged"):
        iwf.flush()


def test_invariant_mode_env(monkeypatch):
    monkeypatch.setenv("REPRO_CHECK_WATERFILL", "1")
    model, universe = STRUCTURES["racked_asym_nic"]()
    iwf = IncrementalWaterfill(model.conn_groups)
    assert iwf._check
    drive(model, universe, 4, check=True)


def test_simconfig_incremental_requires_grouped_model():
    """waterfill='incremental' must insist: the uniform equal-share path
    and custom shares() overrides error instead of silently degrading."""
    from repro.core.events import Op, StepTemplate, ps_resources
    from repro.core.simulator import SimConfig, Simulation
    tpl = [StepTemplate(ops=[Op("d", "downlink", size=1e6)])]
    cfg = SimConfig(resources=ps_resources(1e8, 1), waterfill="incremental")
    with pytest.raises(ValueError, match="grouped bandwidth model"):
        Simulation(cfg).run(tpl, 1)
    with pytest.raises(ValueError, match="unknown waterfill mode"):
        SimConfig(resources=ps_resources(1e8, 1), waterfill="bogus")


def test_add_twice_rejected_and_remove_unknown_rejected():
    model, universe = STRUCTURES["star"]()
    iwf = IncrementalWaterfill(model.conn_groups)
    iwf.add(universe[0])
    with pytest.raises(ValueError, match="already active"):
        iwf.add(universe[0])
    with pytest.raises(KeyError):
        iwf.remove(universe[1])


def test_full_solve_fallback_is_exact():
    """Force the full-solve escape hatch on every flush; results must be
    identical anyway (it is a perf fallback, not a different algorithm)."""
    model, universe = STRUCTURES["grouped"]()

    class Eager(IncrementalWaterfill):
        FULL_FRACTION = 0.0

    rng = random.Random(11)
    iwf = Eager(model.conn_groups)
    active = set()
    for _ in range(60):
        if active and rng.random() < 0.4:
            c = rng.choice(sorted(active))
            active.discard(c)
            iwf.remove(c)
        else:
            c = universe[rng.randrange(len(universe))]
            if c in active:
                continue
            active.add(c)
            iwf.add(c)
        iwf.flush()
        assert iwf.shares == _batch_solve(model, active)
    assert iwf.stats["full_solves"] > 0


# ---------------------------------------------------------------------------
# hypothesis stateful machine (bonus tier; skipped without hypothesis)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    from hypothesis import settings
    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     invariant, rule)
    from hypothesis import strategies as hst

    class WaterfillMachine(RuleBasedStateMachine):
        """Stateful differential test: arbitrary interleavings of joins,
        leaves and flushes keep the incremental cache equal to the batch
        solve of the current membership."""

        @initialize(structure=hst.sampled_from(sorted(STRUCTURES)))
        def setup(self, structure):
            self.model, self.universe = STRUCTURES[structure]()
            self.iwf = IncrementalWaterfill(self.model.conn_groups)
            self.active = set()

        @rule(i=hst.integers(0, 47))
        def join(self, i):
            c = self.universe[i % len(self.universe)]
            if c not in self.active:
                self.active.add(c)
                self.iwf.add(c)

        @rule(i=hst.integers(0, 47))
        def leave(self, i):
            if self.active:
                c = sorted(self.active)[i % len(self.active)]
                self.active.discard(c)
                self.iwf.remove(c)

        @rule()
        def flush(self):
            self.iwf.flush()

        @invariant()
        def matches_batch(self):
            if hasattr(self, "iwf") and not self.iwf.pending:
                assert self.iwf.shares == _batch_solve(self.model,
                                                       self.active)

    WaterfillMachine.TestCase.settings = settings(
        max_examples=25, stateful_step_count=40, deadline=None)
    TestWaterfillMachine = WaterfillMachine.TestCase
